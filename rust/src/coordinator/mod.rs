//! L3 build coordinator: a CI-farm front end over the daemon, scheduling
//! **steps**, not requests.
//!
//! The paper's motivation (§II.C): "the modern software development
//! process encourages a build after each small incremental change … This
//! becomes problematic when we have a high demand for builds but a low
//! throughput of build runtime, which is clogged up by long build time."
//! The coordinator models that pipeline: a queue of build requests over
//! a pool of worker machines (each with its own daemon state, as in the
//! paper's multi-machine setup), each request served by the Docker
//! rebuild path or the injection fast path.
//!
//! ## Step-level fleet scheduling (the default, [`SchedMode::StepLevel`])
//!
//! The per-request worker loop of the seed wasted the parallelism
//! budget: each daemon served one request end-to-end with `jobs: 1`, so
//! one cold build serialized an entire queue of mostly-cached injection
//! requests while cores idled. Following DOCTOR (arXiv:2504.01742 —
//! rebuild efficiency comes from re-orchestrating instructions globally)
//! and Charliecloud's shared build cache (arXiv:2309.00166 —
//! content-addressed caching makes cross-build sharing safe), the
//! coordinator now runs **one shared work-queue of steps** across all
//! queued requests:
//!
//! * every request gets a driver that scans/plans immediately; the ready
//!   set of its step DAG is submitted to one persistent
//!   [`StepPool`](crate::builder::StepPool) whose worker count is the
//!   fleet's global `jobs` budget;
//! * grants go to the request with the **shortest remaining work**
//!   (closest to completion), with a starvation bound so cold builds
//!   still progress — a 1-step injection queued behind a 20-step cold
//!   build no longer waits for it;
//! * **single-flight dedup**: two requests resolving the same step
//!   execution key (same derived layer identity + execution inputs —
//!   see [`crate::builder::cache::flight_key`]) execute it once; both
//!   adopt the resulting layer from the content-addressed store. N
//!   tenants rebuilding off one Dockerfile prefix collapse from N× to
//!   1× execution;
//! * builds sharing a worker daemon serialize their store phases
//!   (scan+plan, finalize, injection patching) on a **per-daemon store
//!   lock**, so concurrent builds never race `LayerStore` writes.
//!
//! Lock ordering (deadlock freedom): daemon store lock → chunk pool;
//! the store lock is never held while waiting on the pool or a flight
//! entry, and pool workers take no store locks (step jobs are pure).
//! Cached steps re-read their stored meta inside the finalize lock, so
//! a build racing an in-place injection of the same layer id always
//! emits a self-consistent image; queuing a rebuild and an in-place
//! injection that *mutate the same layer* concurrently remains the
//! paper's §III.C sharing hazard (last store write wins) — serialize
//! such requests or use `clone_for_redeploy`.
//! Scheduling is invisible in the output: executors are pure and
//! finalize chains per request in step order, so every request's image
//! id and layer tars are bit-identical to serial execution at any
//! `jobs` width ([`SchedMode::PerRequest`] is kept as the measurable
//! baseline and compatibility escape hatch).

pub mod metrics;

pub use metrics::CoordinatorMetrics;

use crate::builder::sched::{RequestTicket, ScheduleAccounting};
use crate::builder::{BuildOptions, CostModel, SchedContext, StepFlight, StepPool};
use crate::daemon::Daemon;
use crate::inject::{InjectMode, InjectOptions};
use crate::registry::{
    ChunkFetchCache, GcReport, PullOptions, PushOptions, PushReport, RemoteRegistry, RepairReport,
    ScrubReport,
};
use crate::Result;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, RwLock, RwLockReadGuard};
use std::time::{Duration, Instant};

/// How a request should be served.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BuildStrategy {
    /// Always the baseline Docker rebuild.
    DockerRebuild,
    /// Always the injection fast path (errors on structural changes).
    Inject,
    /// Injection with downstream cascade (compiled-language projects).
    InjectCascade,
    /// Try injection; fall back to a rebuild when injection refuses
    /// (first build, structural change, compile hazard).
    Auto,
}

/// How the coordinator schedules a batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// The seed behavior: each worker daemon serves one request
    /// end-to-end; a request's steps parallelize only within its own
    /// build. Kept as the bench baseline.
    PerRequest,
    /// One shared step-level worker pool across all queued requests,
    /// with shortest-remaining-work priority and single-flight dedup
    /// (the default).
    StepLevel,
}

/// One CI build request.
#[derive(Clone, Debug)]
pub struct BuildRequest {
    pub id: u64,
    /// Build-context directory (the project checkout).
    pub project: PathBuf,
    pub tag: String,
    pub strategy: BuildStrategy,
}

/// Outcome of one request.
#[derive(Clone, Debug)]
pub struct BuildOutcome {
    pub id: u64,
    pub worker: usize,
    /// What actually ran: "build", "inject", "inject+cascade",
    /// "inject->build" (auto fallback).
    pub strategy_used: String,
    /// Time spent waiting in the queue before a driver picked the
    /// request up (step-level mode admits every request immediately;
    /// its waiting happens per step, inside `service`).
    pub queue_wait: Duration,
    /// Service time (build or inject).
    pub service: Duration,
    pub ok: bool,
    pub detail: String,
    /// Step scheduling accounting (zero in [`SchedMode::PerRequest`]).
    pub sched: ScheduleAccounting,
}

/// Result of one [`BuildCoordinator::maintain`] pass.
#[derive(Clone, Debug)]
pub struct MaintenanceReport {
    pub scrub: ScrubReport,
    /// The anti-entropy round: scrub may delete rotted replica copies,
    /// so repair runs after it (re-copying from surviving replicas)
    /// and before gc (whose sweep should see the converged layout).
    pub repair: RepairReport,
    pub gc: GcReport,
}

/// Result of one [`BuildCoordinator::warm`] pass across the farm.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WarmReport {
    /// Layers fetched across all workers (already-local layers skip).
    pub layers_fetched: usize,
    /// Chunks fetched over the wire — with the shared fetch cache, each
    /// distinct chunk is fetched once for the whole farm.
    pub chunks_fetched: usize,
    /// Chunk fetches satisfied by another worker's in-flight fetch of
    /// the same chunk (the cross-worker dedup).
    pub chunks_shared: usize,
    pub bytes_fetched: u64,
    pub bytes_shared: u64,
    /// Bytes that actually crossed the wire from the origin registry —
    /// the number a warm persistent pull cache drives toward zero.
    pub bytes_from_origin: u64,
    /// Bytes served by the persistent pull-cache tier
    /// ([`BuildCoordinator::warm_with_cache`]).
    pub bytes_from_cache: u64,
    /// Chunk reads the origin served from a non-home replica (a backend
    /// was erring or breaker-open during the warm) — the fleet's view
    /// of origin degradation, aggregated from
    /// [`crate::registry::PullReport::failover_reads`].
    pub failover_reads: u64,
    /// Replica copies origin write-repaired during the warm's reads.
    pub read_repairs: u64,
}

/// A live push permit: while any permit exists, [`BuildCoordinator::maintain`]
/// is excluded — `registry gc` run against a half-committed push would
/// sweep its not-yet-referenced pool chunks as garbage. Dropping the
/// permit completes the quiesce handshake.
///
/// This is the **same-process fast path** only: writers in other
/// processes are excluded by the registry's on-disk leases
/// ([`crate::registry::lease`]), which every push and maintenance pass
/// takes on lease-capable remotes. The permit spares same-process
/// pushes a needless wait for their own coordinator's `maintain` and
/// keeps the handshake cheap when only one process writes.
pub struct PushPermit<'a>(#[allow(dead_code)] RwLockReadGuard<'a, ()>);

/// The coordinator: a step-level scheduler over per-worker daemons.
pub struct BuildCoordinator {
    root: PathBuf,
    workers: usize,
    pub cost: CostModel,
    /// The fleet's step budget, defaulting to `workers`. In
    /// [`SchedMode::StepLevel`] it is global: at most `jobs` steps
    /// execute concurrently across ALL queued requests. In
    /// [`SchedMode::PerRequest`] it is the per-build width each worker's
    /// current request runs at (workers serve independently, so up to
    /// `workers × jobs` steps can overlap — the seed's semantics with
    /// the hard-wired `jobs: 1` removed).
    pub jobs: usize,
    /// The maintenance quiesce handshake: pushes take it shared,
    /// [`Self::maintain`] takes it exclusive. Same-process fast path —
    /// cross-process exclusion is the registry lease protocol's job.
    quiesce: RwLock<()>,
    /// The persistent step pool, created lazily at the first step-level
    /// batch and reused across batches (rebuilt if `jobs` changed).
    pool: Mutex<Option<Arc<StepPool>>>,
    /// Per-worker store locks: builds sharing a daemon serialize their
    /// store phases here (index = worker id).
    store_locks: Vec<Arc<Mutex<()>>>,
}

impl BuildCoordinator {
    /// `root` hosts one daemon state dir per worker (`worker-0`, …).
    pub fn new(root: &std::path::Path, workers: usize) -> BuildCoordinator {
        assert!(workers >= 1);
        BuildCoordinator {
            root: root.to_path_buf(),
            workers,
            cost: CostModel::default(),
            jobs: workers,
            quiesce: RwLock::new(()),
            pool: Mutex::new(None),
            store_locks: (0..workers).map(|_| Arc::new(Mutex::new(()))).collect(),
        }
    }

    /// The persistent shared pool, sized to the current `jobs` budget.
    fn step_pool(&self) -> Arc<StepPool> {
        let mut slot = self.pool.lock().unwrap();
        match &*slot {
            Some(p) if p.jobs() == self.jobs.max(1) => p.clone(),
            _ => {
                let p = Arc::new(StepPool::new(self.jobs.max(1)));
                *slot = Some(p.clone());
                p
            }
        }
    }

    /// Claim a push permit. Held internally by [`Self::push_from`]; a
    /// pipeline pushing outside the coordinator can claim one explicitly
    /// to join the maintenance handshake. Do **not** call `push_from`
    /// while already holding a permit — a queued `maintain` writer could
    /// deadlock the nested read.
    pub fn begin_push(&self) -> PushPermit<'_> {
        PushPermit(self.quiesce.read().unwrap())
    }

    /// Push a tag from one worker's daemon, under a push permit.
    pub fn push_from(
        &self,
        worker: usize,
        tag: &str,
        remote: &RemoteRegistry,
        opts: &PushOptions,
    ) -> Result<PushReport> {
        assert!(worker < self.workers);
        let _permit = self.begin_push();
        let daemon = Daemon::new(&self.root.join(format!("worker-{worker}")))?;
        daemon.push_with(tag, remote, opts)
    }

    /// Scheduled registry maintenance: waits for this process's
    /// in-flight push permits to drop (the same-process fast path), then
    /// — with new local pushes held off — runs `registry scrub` (drop
    /// rotted pool chunks, demote affected layers) and `registry gc`
    /// (mark-and-sweep untagged images, unreferenced layers, orphaned
    /// chunks). Fleet-wide safety comes from the registry itself: on
    /// lease-capable remotes, scrub takes each shard's **exclusive
    /// lease round-robin** — one backend dark at a time, never the
    /// whole pool — while gc holds shard 0's exclusive lease (the
    /// fleet-wide writer lock) for its full mark-and-sweep. Both drain
    /// live pushers in *every* process and fence out expired zombies
    /// before anything is deleted — which is what makes this safe to
    /// run from a cron/`maintain --interval` loop while other machines
    /// keep pushing.
    pub fn maintain(&self, remote: &RemoteRegistry) -> Result<MaintenanceReport> {
        let _quiesced = self.quiesce.write().unwrap();
        // Struct-literal fields evaluate in written order: scrub, then
        // the anti-entropy repair (re-replicating whatever scrub just
        // dropped), then gc over the converged layout.
        Ok(MaintenanceReport {
            scrub: remote.scrub()?,
            repair: remote.repair()?,
            gc: remote.gc()?,
        })
    }

    /// Warm every worker daemon's store from a remote registry before a
    /// batch: each (worker, tag) unit pulls through the chunk-addressed
    /// transport (layers already local are skipped, so re-warming
    /// between batches costs only the delta). Units fan out on one
    /// scoped pool of `jobs` threads — interleaved worker-first so
    /// distinct stores progress concurrently — and all pulls share one
    /// [`ChunkFetchCache`]: workers warming the same tag fetch each
    /// remote chunk **once**, the rest adopt the bytes in memory.
    /// Per-worker store locks keep one worker's pulls serial (the tag
    /// map is a read-modify-write).
    pub fn warm(&self, remote: &RemoteRegistry, tags: &[String], jobs: usize) -> Result<WarmReport> {
        self.warm_with_cache(remote, tags, jobs, None)
    }

    /// [`BuildCoordinator::warm`] with a persistent pull-cache tier: a
    /// site-local on-disk cache ([`crate::registry::PullCache`]) that
    /// every pull reads through before touching the origin. Across
    /// batches (and coordinator restarts — the cache is durable) a
    /// re-warm serves repeat chunks from local disk; the origin sees
    /// only the delta. `WarmReport::bytes_from_origin` vs
    /// `bytes_from_cache` is the measure of how well that worked.
    pub fn warm_with_cache(
        &self,
        remote: &RemoteRegistry,
        tags: &[String],
        jobs: usize,
        pull_cache: Option<crate::registry::PullCache>,
    ) -> Result<WarmReport> {
        let units = self.workers * tags.len();
        if units == 0 {
            return Ok(WarmReport::default());
        }
        let fetch_cache = ChunkFetchCache::new();
        // Split the budget: `outer` concurrent units (capped at the
        // worker count — units sharing a worker serialize on its store
        // lock anyway), each pulling through a `jobs / outer`-wide
        // pipeline. A one-worker farm keeps the full per-pull width the
        // seed had.
        let outer = self.workers.min(jobs.max(1));
        let pull_jobs = (jobs.max(1) / outer).max(1);
        let reports = crate::builder::parallel::scoped_index_map(units, outer, |unit| {
            let worker_id = unit % self.workers;
            let tag = &tags[unit / self.workers];
            let _store = self.store_locks[worker_id].lock().unwrap();
            let daemon = Daemon::new(&self.root.join(format!("worker-{worker_id}")))?;
            daemon.pull_with(
                tag,
                remote,
                &PullOptions {
                    jobs: pull_jobs,
                    fetch_cache: Some(fetch_cache.clone()),
                    pull_cache: pull_cache.clone(),
                    ..Default::default()
                },
            )
        })?;
        let mut warm = WarmReport::default();
        for r in reports {
            warm.layers_fetched += r.layers_fetched;
            warm.chunks_fetched += r.chunks_fetched;
            warm.chunks_shared += r.chunks_shared;
            warm.bytes_fetched += r.bytes_fetched;
            warm.bytes_shared += r.bytes_shared;
            warm.bytes_from_origin += r.bytes_from_origin;
            warm.bytes_from_cache += r.bytes_from_cache;
            warm.failover_reads += r.failover_reads;
            warm.read_repairs += r.read_repairs;
        }
        Ok(warm)
    }

    /// [`BuildCoordinator::warm_with_cache`] for a **hot set**: before
    /// warming, the coordinator resolves each tag's chunk digests at
    /// the origin ([`RemoteRegistry::tag_chunk_digests`]) and pins them
    /// in the pull cache, so later cold-tag traffic cannot evict the
    /// fleet's declared working set. Pins are cumulative across calls;
    /// rotate the hot set with [`crate::registry::PullCache::unpin_all`].
    pub fn warm_pinned(
        &self,
        remote: &RemoteRegistry,
        tags: &[String],
        jobs: usize,
        pull_cache: crate::registry::PullCache,
    ) -> Result<WarmReport> {
        for tag in tags {
            let r = crate::oci::ImageRef::parse(tag);
            pull_cache.pin(&remote.tag_chunk_digests(&r)?)?;
        }
        self.warm_with_cache(remote, tags, jobs, Some(pull_cache))
    }

    /// Process a batch of requests to completion under the default
    /// step-level scheduler; returns outcomes in completion order plus
    /// aggregate metrics.
    pub fn run(&self, requests: Vec<BuildRequest>) -> Result<(Vec<BuildOutcome>, CoordinatorMetrics)> {
        self.run_mode(requests, SchedMode::StepLevel)
    }

    /// Process a batch under an explicit scheduling mode.
    pub fn run_mode(
        &self,
        requests: Vec<BuildRequest>,
        mode: SchedMode,
    ) -> Result<(Vec<BuildOutcome>, CoordinatorMetrics)> {
        match mode {
            SchedMode::PerRequest => self.run_per_request(requests),
            SchedMode::StepLevel => self.run_step_level(requests),
        }
    }

    /// The seed scheduler: `workers` loops, one request end-to-end each.
    /// The fleet `jobs` budget is still plumbed into every build
    /// (requests no longer run artificially serial inside), but steps of
    /// different requests never interleave and nothing dedups.
    fn run_per_request(
        &self,
        requests: Vec<BuildRequest>,
    ) -> Result<(Vec<BuildOutcome>, CoordinatorMetrics)> {
        let submitted = Instant::now();
        let queue: Mutex<VecDeque<BuildRequest>> = Mutex::new(requests.into_iter().collect());
        let outcomes: Mutex<Vec<BuildOutcome>> = Mutex::new(Vec::new());
        let t_start = Instant::now();

        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for worker_id in 0..self.workers {
                let queue = &queue;
                let outcomes = &outcomes;
                let root = self.root.join(format!("worker-{worker_id}"));
                let cost = self.cost;
                let jobs = self.jobs;
                handles.push(scope.spawn(move || -> Result<()> {
                    let mut daemon = Daemon::new(&root)?;
                    daemon.cost = cost;
                    loop {
                        let request = {
                            let mut q = queue.lock().unwrap();
                            match q.pop_front() {
                                Some(r) => r,
                                None => return Ok(()),
                            }
                        };
                        let queue_wait = submitted.elapsed();
                        let outcome = serve(&daemon, &request, worker_id, queue_wait, cost, jobs, None);
                        outcomes.lock().unwrap().push(outcome);
                    }
                }));
            }
            for h in handles {
                h.join().expect("worker panicked")?;
            }
            Ok(())
        })?;

        let outcomes = outcomes.into_inner().unwrap();
        let metrics = CoordinatorMetrics::from_outcomes(&outcomes, t_start.elapsed());
        Ok((outcomes, metrics))
    }

    /// The step-level scheduler: every request is admitted immediately
    /// (one driver each, round-robin over worker daemons); drivers plan
    /// under the per-daemon store lock and submit their ready steps to
    /// the shared persistent pool, where the global `jobs` budget,
    /// shortest-remaining-work priority and single-flight dedup apply
    /// across the whole queue.
    fn run_step_level(
        &self,
        requests: Vec<BuildRequest>,
    ) -> Result<(Vec<BuildOutcome>, CoordinatorMetrics)> {
        let submitted = Instant::now();
        let pool = self.step_pool();
        let flight = StepFlight::new();
        let outcomes: Mutex<Vec<BuildOutcome>> = Mutex::new(Vec::new());
        let t_start = Instant::now();

        let mut daemons = Vec::with_capacity(self.workers);
        for worker_id in 0..self.workers {
            let mut daemon = Daemon::new(&self.root.join(format!("worker-{worker_id}")))?;
            daemon.cost = self.cost;
            daemons.push(daemon);
        }
        let daemons = &daemons;

        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (index, request) in requests.into_iter().enumerate() {
                let worker_id = index % self.workers;
                let sched = SchedContext {
                    pool: pool.clone(),
                    flight: flight.clone(),
                    ticket: RequestTicket::new(),
                    engine: daemons[worker_id].engine_handle(),
                    store_lock: self.store_locks[worker_id].clone(),
                };
                let outcomes = &outcomes;
                let cost = self.cost;
                let jobs = self.jobs;
                handles.push(scope.spawn(move || {
                    let queue_wait = submitted.elapsed();
                    let outcome = serve(
                        &daemons[worker_id],
                        &request,
                        worker_id,
                        queue_wait,
                        cost,
                        jobs,
                        Some(&sched),
                    );
                    outcomes.lock().unwrap().push(outcome);
                }));
            }
            for h in handles {
                h.join().expect("request driver panicked");
            }
        });

        let outcomes = outcomes.into_inner().unwrap();
        let metrics = CoordinatorMetrics::from_outcomes(&outcomes, t_start.elapsed());
        Ok((outcomes, metrics))
    }
}

/// Serve one request on one worker daemon.
fn serve(
    daemon: &Daemon,
    request: &BuildRequest,
    worker: usize,
    queue_wait: Duration,
    cost: CostModel,
    jobs: usize,
    sched: Option<&SchedContext>,
) -> BuildOutcome {
    let t0 = Instant::now();
    let build_opts = BuildOptions {
        no_cache: false,
        cost,
        jobs,
    };
    let inject_opts = |cascade: bool| InjectOptions {
        mode: InjectMode::Implicit,
        cascade,
        clone_for_redeploy: false,
        cost,
        scan_cache: None, // the daemon fills this in
        jobs,
    };
    let build = || {
        daemon
            .build_scheduled(&request.project, &request.tag, &build_opts, sched.cloned())
    };
    let inject = |cascade: bool| {
        daemon.inject_scheduled(
            &request.project,
            &request.tag,
            &request.tag,
            &inject_opts(cascade),
            sched.cloned(),
        )
    };
    let (strategy_used, result): (String, Result<String>) = match request.strategy {
        BuildStrategy::DockerRebuild => (
            "build".into(),
            build().map(|r| format!("{} steps, {} rebuilt", r.steps.len(), r.rebuilt_steps())),
        ),
        BuildStrategy::Inject => (
            "inject".into(),
            inject(false).map(|r| format!("{} file(s) injected", r.files_changed())),
        ),
        BuildStrategy::InjectCascade => (
            "inject+cascade".into(),
            inject(true).map(|r| format!("{} file(s) injected + cascade", r.files_changed())),
        ),
        BuildStrategy::Auto => {
            match inject(false) {
                Ok(r) => ("inject".into(), Ok(format!("{} file(s) injected", r.files_changed()))),
                Err(_) => {
                    // First build / structural change / compile hazard:
                    // fall back to the rebuild path.
                    (
                        "inject->build".into(),
                        build().map(|r| format!("fallback build: {} rebuilt", r.rebuilt_steps())),
                    )
                }
            }
        }
    };
    let service = t0.elapsed();
    let sched_acct = sched.map(|s| s.ticket.accounting()).unwrap_or_default();
    match result {
        Ok(detail) => BuildOutcome {
            id: request.id,
            worker,
            strategy_used,
            queue_wait,
            service,
            ok: true,
            detail,
            sched: sched_acct,
        },
        Err(e) => BuildOutcome {
            id: request.id,
            worker,
            strategy_used,
            queue_wait,
            service,
            ok: false,
            detail: e.to_string(),
            sched: sched_acct,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Scenario, ScenarioKind};

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("lj-coord-{}-{}", tag, std::process::id()))
    }

    #[test]
    fn auto_falls_back_then_injects() {
        let root = tmp("auto");
        let _ = std::fs::remove_dir_all(&root);
        let mut scenario =
            Scenario::generate(ScenarioKind::PythonTiny, &root.join("proj"), 1).unwrap();
        let mut coordinator = BuildCoordinator::new(&root.join("farm"), 1);
        coordinator.cost = CostModel::instant();

        // Round 1: no image yet -> auto must fall back to build.
        let (outcomes, _) = coordinator
            .run(vec![BuildRequest {
                id: 1,
                project: scenario.dir.clone(),
                tag: scenario.tag(),
                strategy: BuildStrategy::Auto,
            }])
            .unwrap();
        assert!(outcomes[0].ok, "{}", outcomes[0].detail);
        assert_eq!(outcomes[0].strategy_used, "inject->build");
        assert!(outcomes[0].sched.steps_scheduled > 0, "steps ran on the pool");

        // Round 2: revision -> auto injects.
        scenario.revise().unwrap();
        let (outcomes, metrics) = coordinator
            .run(vec![BuildRequest {
                id: 2,
                project: scenario.dir.clone(),
                tag: scenario.tag(),
                strategy: BuildStrategy::Auto,
            }])
            .unwrap();
        assert!(outcomes[0].ok, "{}", outcomes[0].detail);
        assert_eq!(outcomes[0].strategy_used, "inject");
        assert_eq!(metrics.completed, 1);
        assert_eq!(metrics.failed, 0);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn pool_processes_batch_across_workers() {
        let root = tmp("pool");
        let _ = std::fs::remove_dir_all(&root);
        // Four distinct tiny projects.
        let mut requests = Vec::new();
        for i in 0..4 {
            let s = Scenario::generate(
                ScenarioKind::PythonTiny,
                &root.join(format!("proj-{i}")),
                i as u64,
            )
            .unwrap();
            // Distinct tags so projects are independent images.
            requests.push(BuildRequest {
                id: i as u64,
                project: s.dir.clone(),
                tag: format!("proj{i}:latest"),
                strategy: BuildStrategy::DockerRebuild,
            });
        }
        let mut coordinator = BuildCoordinator::new(&root.join("farm"), 2);
        coordinator.cost = CostModel::instant();
        let (outcomes, metrics) = coordinator.run(requests).unwrap();
        assert_eq!(outcomes.len(), 4);
        assert!(outcomes.iter().all(|o| o.ok));
        let workers: std::collections::BTreeSet<_> = outcomes.iter().map(|o| o.worker).collect();
        assert!(!workers.is_empty() && workers.len() <= 2);
        assert_eq!(metrics.completed, 4);
        assert!(metrics.throughput_rps > 0.0);
        assert!(metrics.steps_scheduled > 0);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn per_request_mode_matches_step_level_results() {
        // The compatibility path still works and lands the same images.
        let root = tmp("mode");
        let _ = std::fs::remove_dir_all(&root);
        let s = Scenario::generate(ScenarioKind::PythonTiny, &root.join("proj"), 7).unwrap();
        let request = |id| BuildRequest {
            id,
            project: s.dir.clone(),
            tag: s.tag(),
            strategy: BuildStrategy::DockerRebuild,
        };
        let mut a = BuildCoordinator::new(&root.join("farm-a"), 1);
        a.cost = CostModel::instant();
        let (oa, _) = a.run_mode(vec![request(1)], SchedMode::PerRequest).unwrap();
        let mut b = BuildCoordinator::new(&root.join("farm-b"), 1);
        b.cost = CostModel::instant();
        let (ob, _) = b.run_mode(vec![request(2)], SchedMode::StepLevel).unwrap();
        assert!(oa[0].ok && ob[0].ok);
        assert_eq!(oa[0].sched, ScheduleAccounting::default(), "per-request: no pool");
        let da = Daemon::new(&root.join("farm-a").join("worker-0")).unwrap();
        let db = Daemon::new(&root.join("farm-b").join("worker-0")).unwrap();
        assert_eq!(da.image(&s.tag()).unwrap().0, db.image(&s.tag()).unwrap().0);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn warm_pulls_tags_into_every_worker() {
        let root = tmp("warm");
        let _ = std::fs::remove_dir_all(&root);
        // Seed machine builds and pushes.
        let mut seed = crate::daemon::Daemon::new(&root.join("seed")).unwrap();
        seed.cost = CostModel::instant();
        let scenario = Scenario::generate(ScenarioKind::PythonTiny, &root.join("proj"), 3).unwrap();
        seed.build(&scenario.dir, &scenario.tag()).unwrap();
        let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
        seed.push(&scenario.tag(), &remote).unwrap();

        let coordinator = BuildCoordinator::new(&root.join("farm"), 2);
        let tags = vec![scenario.tag()];
        let warm = coordinator.warm(&remote, &tags, 2).unwrap();
        assert!(warm.layers_fetched > 0, "cold farm must fetch layers");
        for w in 0..2 {
            let daemon = crate::daemon::Daemon::new(&root.join("farm").join(format!("worker-{w}")))
                .unwrap();
            assert!(daemon.verify_image(&scenario.tag()).unwrap(), "worker {w} warm");
        }
        // Cross-worker dedup: the two workers pulled the same tag, so
        // every distinct chunk crossed the wire once — the second
        // worker's copies were shared, not re-fetched.
        assert!(warm.chunks_fetched > 0);
        assert!(
            warm.chunks_shared >= warm.chunks_fetched,
            "second worker must share the first's fetches: {warm:?}"
        );
        // Re-warming is a no-op: every layer already local.
        assert_eq!(coordinator.warm(&remote, &tags, 2).unwrap().layers_fetched, 0);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn warm_pinned_keeps_hot_tag_chunks_resident() {
        let root = tmp("warmpin");
        let _ = std::fs::remove_dir_all(&root);
        let mut seed = crate::daemon::Daemon::new(&root.join("seed")).unwrap();
        seed.cost = CostModel::instant();
        let scenario = Scenario::generate(ScenarioKind::PythonTiny, &root.join("proj"), 5).unwrap();
        seed.build(&scenario.dir, &scenario.tag()).unwrap();
        let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
        seed.push(&scenario.tag(), &remote).unwrap();

        // A 1-byte budget would evict every chunk as it lands — unless
        // the hot tag's digests are pinned first, in which case the
        // cache keeps them and runs over budget by design.
        let cache = crate::registry::PullCache::open(&root.join("cache"), 1).unwrap();
        let coordinator = BuildCoordinator::new(&root.join("farm"), 1);
        let tags = vec![scenario.tag()];
        let warm = coordinator.warm_pinned(&remote, &tags, 1, cache.clone()).unwrap();
        assert!(warm.layers_fetched > 0);
        let stats = cache.stats();
        assert!(stats.entries > 0, "pinned chunks must stay resident: {stats:?}");
        assert!(stats.pinned_bytes > 0 && stats.pinned_bytes == stats.bytes);
        assert!(stats.bytes > stats.budget, "pins hold the cache over budget");
        // Every digest the origin lists for the tag is resident.
        let digests = remote
            .tag_chunk_digests(&crate::oci::ImageRef::parse(&scenario.tag()))
            .unwrap();
        assert_eq!(stats.entries, digests.len() as u64);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn maintain_quiesces_in_flight_pushes() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let root = tmp("maintain");
        let _ = std::fs::remove_dir_all(&root);
        let coordinator = BuildCoordinator::new(&root.join("farm"), 1);
        // Seed worker-0 with two images: one stays tagged, one becomes
        // garbage for gc to prove it still collects.
        let mut worker = crate::daemon::Daemon::new(&root.join("farm").join("worker-0")).unwrap();
        worker.cost = CostModel::instant();
        let keep_ctx = root.join("p-keep");
        let garbage_ctx = root.join("p-garbage");
        for (dir, main) in [(&keep_ctx, "print('keep')\n"), (&garbage_ctx, "print('garbage')\n")] {
            std::fs::create_dir_all(dir).unwrap();
            std::fs::write(
                dir.join("Dockerfile"),
                "FROM python:alpine\nCOPY main.py main.py\nCMD [\"python\", \"main.py\"]\n",
            )
            .unwrap();
            std::fs::write(dir.join("main.py"), main).unwrap();
        }
        worker.build(&keep_ctx, "keep:v1").unwrap();
        worker.build(&garbage_ctx, "garbage:v1").unwrap();

        let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
        coordinator
            .push_from(0, "garbage:v1", &remote, &PushOptions::default())
            .unwrap();
        remote.untag(&crate::oci::ImageRef::parse("garbage:v1")).unwrap();

        // The handshake: while a queued push holds its permit, maintain
        // must wait — gc cannot sweep chunks the push is about to
        // reference.
        let done = AtomicBool::new(false);
        let report = std::thread::scope(|scope| {
            let permit = coordinator.begin_push();
            let handle = scope.spawn(|| {
                let r = coordinator.maintain(&remote);
                done.store(true, Ordering::SeqCst);
                r
            });
            std::thread::sleep(Duration::from_millis(100));
            assert!(
                !done.load(Ordering::SeqCst),
                "maintain must block on the in-flight push permit"
            );
            // The queued push completes under the held permit: its
            // chunks, manifests and tag commit before gc can mark.
            worker.push("keep:v1", &remote).unwrap();
            drop(permit);
            handle.join().unwrap().unwrap()
        });
        assert!(report.gc.images_dropped >= 1, "untagged image must be collected");
        // Everything the concurrent push referenced survived the sweep:
        // a cold machine can still pull and verify the tag.
        let puller = crate::daemon::Daemon::new(&root.join("puller")).unwrap();
        puller.pull("keep:v1", &remote).unwrap();
        assert!(puller.verify_image("keep:v1").unwrap());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn failed_requests_are_reported_not_fatal() {
        let root = tmp("fail");
        let _ = std::fs::remove_dir_all(&root);
        let mut coordinator = BuildCoordinator::new(&root.join("farm"), 1);
        coordinator.cost = CostModel::instant();
        let (outcomes, metrics) = coordinator
            .run(vec![BuildRequest {
                id: 9,
                project: root.join("nonexistent"),
                tag: "ghost:1".into(),
                strategy: BuildStrategy::DockerRebuild,
            }])
            .unwrap();
        assert!(!outcomes[0].ok);
        assert_eq!(metrics.failed, 1);
        std::fs::remove_dir_all(&root).unwrap();
    }
}
