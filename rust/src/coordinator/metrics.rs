//! Aggregate CI metrics: the throughput/latency numbers the pipeline
//! experiments report.

use super::BuildOutcome;
use crate::stats::percentile;
use std::time::Duration;

#[derive(Clone, Debug)]
pub struct CoordinatorMetrics {
    pub completed: usize,
    pub failed: usize,
    /// Requests per second over the whole batch.
    pub throughput_rps: f64,
    pub mean_service: Duration,
    pub p50_service: Duration,
    pub p95_service: Duration,
    pub max_service: Duration,
    pub wall: Duration,
    /// Step jobs executed on the shared pool across the batch (the
    /// fleet's actual toolchain work).
    pub steps_scheduled: usize,
    /// Steps resolved by single-flight dedup — work a per-request
    /// scheduler would have executed again.
    pub steps_deduped: usize,
    /// Steps adopted byte-for-byte from old images (DAG adoption).
    pub steps_adopted: usize,
    /// Transient step failures absorbed by retries across the batch —
    /// work the fleet redid without failing any request.
    pub steps_retried: usize,
}

impl CoordinatorMetrics {
    pub fn from_outcomes(outcomes: &[BuildOutcome], wall: Duration) -> CoordinatorMetrics {
        let completed = outcomes.iter().filter(|o| o.ok).count();
        let failed = outcomes.len() - completed;
        let services: Vec<f64> = outcomes.iter().map(|o| o.service.as_secs_f64()).collect();
        let (mean, p50, p95, max) = if services.is_empty() {
            (0.0, 0.0, 0.0, 0.0)
        } else {
            (
                services.iter().sum::<f64>() / services.len() as f64,
                percentile(&services, 50.0),
                percentile(&services, 95.0),
                services.iter().copied().fold(0.0, f64::max),
            )
        };
        CoordinatorMetrics {
            completed,
            failed,
            throughput_rps: if wall.as_secs_f64() > 0.0 {
                outcomes.len() as f64 / wall.as_secs_f64()
            } else {
                0.0
            },
            mean_service: Duration::from_secs_f64(mean),
            p50_service: Duration::from_secs_f64(p50),
            p95_service: Duration::from_secs_f64(p95),
            max_service: Duration::from_secs_f64(max),
            wall,
            steps_scheduled: outcomes.iter().map(|o| o.sched.steps_scheduled).sum(),
            steps_deduped: outcomes.iter().map(|o| o.sched.steps_deduped).sum(),
            steps_adopted: outcomes.iter().map(|o| o.sched.steps_adopted).sum(),
            steps_retried: outcomes.iter().map(|o| o.sched.steps_retried).sum(),
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "{} ok / {} failed | {:.2} req/s | service mean {} p50 {} p95 {} | wall {} | \
             steps {} scheduled / {} deduped / {} adopted / {} retried",
            self.completed,
            self.failed,
            self.throughput_rps,
            crate::util::human_duration(self.mean_service),
            crate::util::human_duration(self.p50_service),
            crate::util::human_duration(self.p95_service),
            crate::util::human_duration(self.wall),
            self.steps_scheduled,
            self.steps_deduped,
            self.steps_adopted,
            self.steps_retried,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(ok: bool, ms: u64) -> BuildOutcome {
        BuildOutcome {
            id: 0,
            worker: 0,
            strategy_used: "build".into(),
            queue_wait: Duration::ZERO,
            service: Duration::from_millis(ms),
            ok,
            detail: String::new(),
            sched: crate::builder::ScheduleAccounting {
                steps_scheduled: 2,
                steps_deduped: 1,
                steps_adopted: 0,
                steps_retried: 1,
            },
        }
    }

    #[test]
    fn metrics_aggregate() {
        let outcomes = vec![outcome(true, 10), outcome(true, 20), outcome(false, 30)];
        let m = CoordinatorMetrics::from_outcomes(&outcomes, Duration::from_secs(1));
        assert_eq!(m.completed, 2);
        assert_eq!(m.failed, 1);
        assert!((m.throughput_rps - 3.0).abs() < 1e-9);
        assert_eq!(m.mean_service, Duration::from_millis(20));
        assert_eq!(m.max_service, Duration::from_millis(30));
        assert_eq!(m.steps_scheduled, 6);
        assert_eq!(m.steps_deduped, 3);
        assert_eq!(m.steps_retried, 3);
        assert!(m.summary().contains("2 ok / 1 failed"));
        assert!(m.summary().contains("6 scheduled / 3 deduped"));
        assert!(m.summary().contains("3 retried"));
    }

    #[test]
    fn empty_outcomes() {
        let m = CoordinatorMetrics::from_outcomes(&[], Duration::from_secs(1));
        assert_eq!(m.completed + m.failed, 0);
        assert_eq!(m.mean_service, Duration::ZERO);
    }
}
