//! File-set diff: which members of a layer's archive differ from the
//! current build context.

use crate::builder::BuildContext;
use crate::hash::{ChunkDigest, HashEngine};
use crate::tar::{TarReader, TypeFlag};
use crate::Result;

/// What happened to one file between the archived layer and the context.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FileChangeKind {
    /// Present in both, content differs.
    Modified,
    /// Present only in the new context.
    Added,
    /// Present only in the old layer.
    Removed,
}

/// One changed file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FileChange {
    /// Archive path inside the layer tar.
    pub archive_path: String,
    /// Context-relative source path (`None` for removals).
    pub context_path: Option<String>,
    pub kind: FileChangeKind,
}

/// Compare a COPY/ADD layer's tar against what the instruction would copy
/// from the current context.
///
/// `selected` is the `(sub_path, file)` list from
/// [`BuildContext::select`], and `path_of` maps a sub-path to the archive
/// path the builder would use (the caller knows the dst/workdir rules).
pub fn diff_trees(
    layer_tar: &[u8],
    _ctx: &BuildContext,
    selected: &[(String, &crate::builder::ContextFile)],
    path_of: &dyn Fn(&str) -> String,
    engine: &dyn HashEngine,
) -> Result<Vec<FileChange>> {
    let reader = TarReader::new(layer_tar)?;
    let mut changes = Vec::new();
    let mut seen = std::collections::BTreeSet::new();

    for (sub, f) in selected {
        let archive_path = path_of(sub);
        seen.insert(archive_path.clone());
        match reader.find(&archive_path) {
            None => changes.push(FileChange {
                archive_path,
                context_path: Some(f.rel_path.clone()),
                kind: FileChangeKind::Added,
            }),
            Some(entry) => {
                // Compare by chunk-digest root: the context already carries
                // it, so only the archived side needs hashing — and the
                // batched engine does that.
                let archived = ChunkDigest::compute(entry.data(layer_tar), engine);
                if archived.root != f.digest {
                    changes.push(FileChange {
                        archive_path,
                        context_path: Some(f.rel_path.clone()),
                        kind: FileChangeKind::Modified,
                    });
                }
            }
        }
    }
    for entry in reader.entries() {
        if entry.typeflag == TypeFlag::Regular && !seen.contains(&entry.name) {
            changes.push(FileChange {
                archive_path: entry.name.clone(),
                context_path: None,
                kind: FileChangeKind::Removed,
            });
        }
    }
    Ok(changes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::NativeEngine;
    use crate::tar::TarBuilder;
    use std::path::PathBuf;

    fn ctx_with(files: &[(&str, &str)]) -> (BuildContext, PathBuf) {
        let d = std::env::temp_dir().join(format!(
            "lj-fsdiff-{}-{}",
            files.len(),
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        for (p, c) in files {
            let path = d.join(p);
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(path, c).unwrap();
        }
        (BuildContext::scan(&d, &NativeEngine::new()).unwrap(), d)
    }

    fn identity(sub: &str) -> String {
        sub.to_string()
    }

    #[test]
    fn detects_modified_added_removed() {
        let eng = NativeEngine::new();
        let mut b = TarBuilder::new();
        b.append_file("main.py", b"print('v1')\n").unwrap();
        b.append_file("gone.py", b"bye\n").unwrap();
        let tar = b.finish();

        let (ctx, d) = ctx_with(&[("main.py", "print('v2')\n"), ("new.py", "hi\n")]);
        let selected = ctx.select(".");
        let changes = diff_trees(&tar, &ctx, &selected, &identity, &eng).unwrap();
        let kind_of = |p: &str| {
            changes
                .iter()
                .find(|c| c.archive_path == p)
                .map(|c| c.kind.clone())
        };
        assert_eq!(kind_of("main.py"), Some(FileChangeKind::Modified));
        assert_eq!(kind_of("new.py"), Some(FileChangeKind::Added));
        assert_eq!(kind_of("gone.py"), Some(FileChangeKind::Removed));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn no_changes_is_empty() {
        let eng = NativeEngine::new();
        let (ctx, d) = ctx_with(&[("a.py", "same\n")]);
        let mut b = TarBuilder::new();
        b.append_file("a.py", b"same\n").unwrap();
        let tar = b.finish();
        let selected = ctx.select(".");
        let changes = diff_trees(&tar, &ctx, &selected, &identity, &eng).unwrap();
        assert!(changes.is_empty(), "{changes:?}");
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn path_mapping_is_respected() {
        let eng = NativeEngine::new();
        let (ctx, d) = ctx_with(&[("app.py", "x\n")]);
        let mut b = TarBuilder::new();
        b.append_file("root/app.py", b"x\n").unwrap();
        let tar = b.finish();
        let selected = ctx.select(".");
        let map = |sub: &str| format!("root/{sub}");
        let changes = diff_trees(&tar, &ctx, &selected, &map, &eng).unwrap();
        assert!(changes.is_empty(), "{changes:?}");
        std::fs::remove_dir_all(&d).unwrap();
    }
}
