//! Change detection: the paper's `diff` step (Fig. 3).
//!
//! * [`myers`] — line-level diff (Myers O(ND)) between two revisions of a
//!   source file, with unified-diff rendering and patch application;
//! * [`fsdiff`] — file-set diff between a layer's archived tree and the
//!   current build context, which is how the injector finds *which* files
//!   of a `COPY`/`ADD` layer changed.

pub mod fsdiff;
pub mod myers;

pub use fsdiff::{diff_trees, FileChange, FileChangeKind};
pub use myers::{diff_lines, render_unified, DiffOp};
