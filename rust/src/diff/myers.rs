//! Myers O(ND) line diff.
//!
//! "Newly edited code can be compared side by side against the original
//! code to identify where the changes occur" (paper §III.A, Fig. 3).
//! Interpreted languages are "written in literal text and run as is", so
//! a text diff is a complete description of the change — the property
//! the whole injection method rests on.

/// One diff operation over lines.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DiffOp {
    /// `n` lines equal in both revisions.
    Equal(usize),
    /// `n` lines deleted from the old revision.
    Delete(usize),
    /// Lines inserted from the new revision.
    Insert(Vec<String>),
}

/// Compute a minimal line diff (Myers greedy O(ND)).
pub fn diff_lines(old: &str, new: &str) -> Vec<DiffOp> {
    let a: Vec<&str> = old.lines().collect();
    let b: Vec<&str> = new.lines().collect();
    let (n, m) = (a.len(), b.len());
    if n == 0 && m == 0 {
        return Vec::new();
    }
    let max = n + m;
    // V[k + max] = furthest x on diagonal k; trace stores V per step d.
    let mut v = vec![0usize; 2 * max + 1];
    let mut trace: Vec<Vec<usize>> = Vec::new();
    let mut found_d = None;
    'outer: for d in 0..=max {
        trace.push(v.clone());
        for k in (0..=d).map(|i| 2 * i as isize - d as isize) {
            let idx = (k + max as isize) as usize;
            let mut x = if k == -(d as isize) || (k != d as isize && v[idx - 1] < v[idx + 1]) {
                v[idx + 1]
            } else {
                v[idx - 1] + 1
            };
            let mut y = (x as isize - k) as usize;
            while x < n && y < m && a[x] == b[y] {
                x += 1;
                y += 1;
            }
            v[idx] = x;
            if x >= n && y >= m {
                found_d = Some(d);
                break 'outer;
            }
        }
    }
    let d_final = found_d.expect("diff must terminate");

    // Backtrack.
    let mut ops_rev: Vec<(char, usize)> = Vec::new(); // ('=', line) / ('-', old line) / ('+', new line)
    let (mut x, mut y) = (n, m);
    for d in (1..=d_final).rev() {
        let vprev = &trace[d];
        let k = x as isize - y as isize;
        let idx = (k + max as isize) as usize;
        let down = k == -(d as isize) || (k != d as isize && vprev[idx - 1] < vprev[idx + 1]);
        let prev_k = if down { k + 1 } else { k - 1 };
        let prev_x = vprev[(prev_k + max as isize) as usize];
        let prev_y = (prev_x as isize - prev_k) as usize;
        // Snake.
        while x > prev_x && y > prev_y {
            ops_rev.push(('=', x - 1));
            x -= 1;
            y -= 1;
        }
        if down {
            ops_rev.push(('+', y - 1));
            y -= 1;
        } else {
            ops_rev.push(('-', x - 1));
            x -= 1;
        }
    }
    while x > 0 && y > 0 {
        ops_rev.push(('=', x - 1));
        x -= 1;
        y -= 1;
    }

    // Fold into DiffOps.
    let mut out: Vec<DiffOp> = Vec::new();
    for (tag, line) in ops_rev.into_iter().rev() {
        match tag {
            '=' => match out.last_mut() {
                Some(DiffOp::Equal(c)) => *c += 1,
                _ => out.push(DiffOp::Equal(1)),
            },
            '-' => match out.last_mut() {
                Some(DiffOp::Delete(c)) => *c += 1,
                _ => out.push(DiffOp::Delete(1)),
            },
            '+' => {
                let text = b[line].to_string();
                match out.last_mut() {
                    Some(DiffOp::Insert(lines)) => lines.push(text),
                    _ => out.push(DiffOp::Insert(vec![text])),
                }
            }
            _ => unreachable!(),
        }
    }
    out
}

/// Number of changed lines (insertions + deletions).
pub fn changed_lines(ops: &[DiffOp]) -> usize {
    ops.iter()
        .map(|op| match op {
            DiffOp::Equal(_) => 0,
            DiffOp::Delete(n) => *n,
            DiffOp::Insert(lines) => lines.len(),
        })
        .sum()
}

/// Apply a diff to the old text, reproducing the new text.
pub fn apply(old: &str, ops: &[DiffOp]) -> String {
    let a: Vec<&str> = old.lines().collect();
    let mut out: Vec<String> = Vec::new();
    let mut i = 0;
    for op in ops {
        match op {
            DiffOp::Equal(n) => {
                out.extend(a[i..i + n].iter().map(|s| s.to_string()));
                i += n;
            }
            DiffOp::Delete(n) => i += n,
            DiffOp::Insert(lines) => out.extend(lines.iter().cloned()),
        }
    }
    let mut s = out.join("\n");
    if !s.is_empty() {
        s.push('\n');
    }
    s
}

/// Render a compact unified-style diff (Fig. 3 of the paper).
pub fn render_unified(old: &str, ops: &[DiffOp]) -> String {
    let a: Vec<&str> = old.lines().collect();
    let mut out = String::new();
    let mut i = 0;
    for op in ops {
        match op {
            DiffOp::Equal(n) => i += n,
            DiffOp::Delete(n) => {
                for line in &a[i..i + n] {
                    out.push_str(&format!("- {line}\n"));
                }
                i += n;
            }
            DiffOp::Insert(lines) => {
                for line in lines {
                    out.push_str(&format!("+ {line}\n"));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn identical_texts() {
        let ops = diff_lines("a\nb\nc\n", "a\nb\nc\n");
        assert_eq!(ops, vec![DiffOp::Equal(3)]);
        assert_eq!(changed_lines(&ops), 0);
    }

    #[test]
    fn pure_append_is_one_insert() {
        // The paper's scenarios append lines to a script.
        let old = "print('hello')\n";
        let new = "print('hello')\nprint('extra')\n";
        let ops = diff_lines(old, new);
        assert_eq!(
            ops,
            vec![DiffOp::Equal(1), DiffOp::Insert(vec!["print('extra')".into()])]
        );
        assert_eq!(changed_lines(&ops), 1);
        assert_eq!(apply(old, &ops), new);
    }

    #[test]
    fn deletion_and_replacement() {
        let old = "a\nb\nc\n";
        let new = "a\nX\nc\n";
        let ops = diff_lines(old, new);
        assert_eq!(changed_lines(&ops), 2); // -b +X
        assert_eq!(apply(old, &ops), new);
        let rendered = render_unified(old, &ops);
        assert!(rendered.contains("- b"));
        assert!(rendered.contains("+ X"));
    }

    #[test]
    fn empty_cases() {
        assert_eq!(diff_lines("", ""), vec![]);
        let ops = diff_lines("", "x\ny\n");
        assert_eq!(apply("", &ops), "x\ny\n");
        let ops = diff_lines("x\ny\n", "");
        assert_eq!(apply("x\ny\n", &ops), "");
    }

    #[test]
    fn round_trip_property() {
        prop::check("apply(old, diff(old,new)) == new", 80, |g| {
            let gen_text = |g: &mut prop::Gen| -> String {
                let n = g.len(0, 30);
                (0..n)
                    .map(|_| format!("line{}\n", g.below(8)))
                    .collect::<String>()
            };
            let old = gen_text(g);
            let new = gen_text(g);
            let ops = diff_lines(&old, &new);
            let applied = apply(&old, &ops);
            // lines()-based reconstruction normalizes a missing trailing
            // newline; our generator always emits one, so equality is exact.
            if applied == new {
                Ok(())
            } else {
                Err(format!("old={old:?} new={new:?} got={applied:?}"))
            }
        });
    }

    #[test]
    fn minimality_on_small_edit() {
        // 1000-line file, one line appended: the diff must be O(1) in size.
        let old: String = (0..1000).map(|i| format!("line {i}\n")).collect();
        let new = format!("{old}appended\n");
        let ops = diff_lines(&old, &new);
        assert_eq!(changed_lines(&ops), 1);
    }
}
