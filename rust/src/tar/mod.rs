//! A from-scratch ustar (POSIX.1-1988 tar) writer/reader.
//!
//! Every layer's content lives in a `layer.tar` (paper Table III-A), and
//! `docker save` bundles are tars of tars. The injection fast path needs
//! more than archive/extract: it must **locate a member's byte range** so
//! a patch can be spliced in place and only the affected chunks re-hashed.
//! [`replace_file`] returns exactly the byte ranges it touched, which is
//! what feeds [`crate::hash::ChunkDigest::update`].
//!
//! Archives are deterministic: fixed mtime/uid/gid, sorted directory
//! walks, zero padding — so a layer's digest depends only on its content.

mod header;
mod reader;
mod writer;

pub use header::{Header, TypeFlag, BLOCK_SIZE};
pub use reader::{Entry, TarReader};
pub use writer::TarBuilder;

use crate::{Error, Result};
use std::path::Path;

/// Archive a directory tree into a deterministic tar (sorted walk,
/// normalized metadata). Paths in the archive are relative to `dir`.
pub fn tar_dir(dir: &Path) -> Result<Vec<u8>> {
    let mut b = TarBuilder::new();
    append_tree(&mut b, dir, "")?;
    Ok(b.finish())
}

fn append_tree(b: &mut TarBuilder, dir: &Path, prefix: &str) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let name = entry.file_name().to_string_lossy().into_owned();
        let arc_path = if prefix.is_empty() {
            name.clone()
        } else {
            format!("{}/{}", prefix, name)
        };
        if entry.file_type()?.is_dir() {
            b.append_dir(&arc_path)?;
            append_tree(b, &entry.path(), &arc_path)?;
        } else {
            let data = std::fs::read(entry.path())?;
            b.append_file(&arc_path, &data)?;
        }
    }
    Ok(())
}

/// Extract an archive into a directory (creates it if needed).
pub fn untar_to(bytes: &[u8], dir: &Path) -> Result<usize> {
    std::fs::create_dir_all(dir)?;
    let reader = TarReader::new(bytes)?;
    let mut n = 0;
    for entry in reader.entries() {
        let safe = sanitize(&entry.name)?;
        let out = dir.join(&safe);
        match entry.typeflag {
            TypeFlag::Directory => std::fs::create_dir_all(&out)?,
            TypeFlag::Regular => {
                if let Some(parent) = out.parent() {
                    std::fs::create_dir_all(parent)?;
                }
                std::fs::write(&out, entry.data(bytes))?;
                n += 1;
            }
        }
    }
    Ok(n)
}

/// Reject absolute paths and `..` traversal in archive member names.
fn sanitize(name: &str) -> Result<std::path::PathBuf> {
    let p = Path::new(name);
    if p.is_absolute() {
        return Err(Error::Tar(format!("absolute member path: {name}")));
    }
    for comp in p.components() {
        if matches!(comp, std::path::Component::ParentDir) {
            return Err(Error::Tar(format!("path traversal in member: {name}")));
        }
    }
    Ok(p.to_path_buf())
}

/// Replace (or insert) a regular-file member's contents **in place**,
/// splicing the archive buffer. Returns the byte ranges of `tar` that
/// changed, for incremental re-hashing:
///
/// * same padded size → only the member's header (size/checksum fields)
///   and data region change: two small ranges;
/// * different padded size → the splice shifts the tail: one range from
///   the member's header to the (new) end of the archive.
pub fn replace_file(
    tar: &mut Vec<u8>,
    name: &str,
    new_data: &[u8],
) -> Result<Vec<std::ops::Range<u64>>> {
    let reader = TarReader::new(tar)?;
    let entry = reader
        .entries()
        .into_iter()
        .find(|e| e.name == name && e.typeflag == TypeFlag::Regular)
        .ok_or_else(|| Error::Tar(format!("member not found: {name}")))?;

    let old_padded = padded(entry.size as usize);
    let new_padded = padded(new_data.len());

    // Rewrite the header with the new size.
    let mut hdr = Header::for_file(name, new_data.len() as u64)?;
    hdr.finalize_checksum();
    let hdr_bytes = hdr.to_bytes();
    tar[entry.header_offset..entry.header_offset + BLOCK_SIZE].copy_from_slice(&hdr_bytes);

    let data_start = entry.data_offset;
    if new_padded == old_padded {
        // In-place overwrite; zero the padding tail.
        tar[data_start..data_start + new_data.len()].copy_from_slice(new_data);
        for b in &mut tar[data_start + new_data.len()..data_start + new_padded] {
            *b = 0;
        }
        Ok(vec![
            entry.header_offset as u64..(entry.header_offset + BLOCK_SIZE) as u64,
            data_start as u64..(data_start + new_padded) as u64,
        ])
    } else {
        // Splice: everything from the data region to EOF shifts.
        let mut padded_data = vec![0u8; new_padded];
        padded_data[..new_data.len()].copy_from_slice(new_data);
        tar.splice(data_start..data_start + old_padded, padded_data);
        Ok(vec![entry.header_offset as u64..tar.len() as u64])
    }
}

/// Insert a new regular-file member, **keeping members name-sorted**
/// (the builder archives files in sorted order, and injection must stay
/// byte-equivalent to a rebuild — the `inject == rebuild` property).
/// Returns the changed byte range (insertion point to new EOF).
pub fn insert_file(
    tar: &mut Vec<u8>,
    name: &str,
    data: &[u8],
) -> Result<Vec<std::ops::Range<u64>>> {
    let reader = TarReader::new(tar)?;
    if reader.find(name).is_some() {
        return replace_file(tar, name, data);
    }
    // Sorted insertion point: before the first member that orders after
    // `name`; otherwise after the last member's padded data.
    let entries = reader.entries();
    let insert_at = entries
        .iter()
        .find(|e| e.name.as_str() > name)
        .map(|e| e.header_offset)
        .unwrap_or_else(|| {
            entries
                .last()
                .map(|e| e.data_offset + padded(e.size as usize))
                .unwrap_or(0)
        });
    let mut hdr = Header::for_file(name, data.len() as u64)?;
    hdr.finalize_checksum();
    let mut piece = Vec::with_capacity(BLOCK_SIZE + padded(data.len()));
    piece.extend_from_slice(&hdr.to_bytes());
    piece.extend_from_slice(data);
    piece.extend(std::iter::repeat(0u8).take(padded(data.len()) - data.len()));
    tar.splice(insert_at..insert_at, piece);
    Ok(vec![insert_at as u64..tar.len() as u64])
}

/// Remove a regular-file member. Returns the changed byte range (removal
/// point to new EOF).
pub fn remove_file(tar: &mut Vec<u8>, name: &str) -> Result<Vec<std::ops::Range<u64>>> {
    let reader = TarReader::new(tar)?;
    let entry = reader
        .entries()
        .into_iter()
        .find(|e| e.name == name && e.typeflag == TypeFlag::Regular)
        .ok_or_else(|| Error::Tar(format!("member not found: {name}")))?;
    let end = entry.data_offset + padded(entry.size as usize);
    tar.splice(entry.header_offset..end, std::iter::empty());
    Ok(vec![entry.header_offset as u64..tar.len() as u64])
}

/// Round a size up to the 512-byte block boundary.
pub fn padded(size: usize) -> usize {
    size.div_ceil(BLOCK_SIZE) * BLOCK_SIZE
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("lj-tar-{}-{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn dir_round_trip() {
        let src = tmpdir("src");
        std::fs::create_dir_all(src.join("pkg/sub")).unwrap();
        std::fs::write(src.join("main.py"), b"print('hi')\n").unwrap();
        std::fs::write(src.join("pkg/mod.py"), b"x = 1\n").unwrap();
        std::fs::write(src.join("pkg/sub/deep.py"), vec![0xaa; 1500]).unwrap();
        let tar = tar_dir(&src).unwrap();
        assert_eq!(tar.len() % BLOCK_SIZE, 0);

        let dst = tmpdir("dst");
        let n = untar_to(&tar, &dst).unwrap();
        assert_eq!(n, 3);
        assert_eq!(std::fs::read(dst.join("main.py")).unwrap(), b"print('hi')\n");
        assert_eq!(std::fs::read(dst.join("pkg/sub/deep.py")).unwrap(), vec![0xaa; 1500]);
        std::fs::remove_dir_all(&src).unwrap();
        std::fs::remove_dir_all(&dst).unwrap();
    }

    #[test]
    fn deterministic_archives() {
        let src = tmpdir("det");
        std::fs::write(src.join("b.txt"), b"bbb").unwrap();
        std::fs::write(src.join("a.txt"), b"aaa").unwrap();
        let t1 = tar_dir(&src).unwrap();
        let t2 = tar_dir(&src).unwrap();
        assert_eq!(t1, t2);
        std::fs::remove_dir_all(&src).unwrap();
    }

    #[test]
    fn replace_same_padded_size_is_local() {
        let mut b = TarBuilder::new();
        b.append_file("a.py", b"aaaa").unwrap();
        b.append_file("b.py", &vec![b'b'; 600]).unwrap();
        b.append_file("c.py", b"cccc").unwrap();
        let mut tar = b.finish();
        let before_len = tar.len();

        // 600 -> 700 bytes: both pad to 1024, so the change must be local.
        let ranges = replace_file(&mut tar, "b.py", &vec![b'B'; 700]).unwrap();
        assert_eq!(tar.len(), before_len);
        assert_eq!(ranges.len(), 2);
        let total_changed: u64 = ranges.iter().map(|r| r.end - r.start).sum();
        assert!(total_changed <= (BLOCK_SIZE + 1024) as u64);

        let r = TarReader::new(&tar).unwrap();
        let names: Vec<_> = r.entries().iter().map(|e| e.name.clone()).collect();
        assert_eq!(names, vec!["a.py", "b.py", "c.py"]);
        let eb = r.entries().into_iter().find(|e| e.name == "b.py").unwrap();
        assert_eq!(eb.data(&tar), &vec![b'B'; 700][..]);
    }

    #[test]
    fn replace_different_size_splices() {
        let mut b = TarBuilder::new();
        b.append_file("a.py", b"aaaa").unwrap();
        b.append_file("b.py", b"bb").unwrap();
        b.append_file("c.py", b"cccc").unwrap();
        let mut tar = b.finish();
        let big = vec![b'B'; 2000];
        replace_file(&mut tar, "b.py", &big).unwrap();
        assert_eq!(tar.len() % BLOCK_SIZE, 0);
        let r = TarReader::new(&tar).unwrap();
        let eb = r.entries().into_iter().find(|e| e.name == "b.py").unwrap();
        assert_eq!(eb.data(&tar), &big[..]);
        let ec = r.entries().into_iter().find(|e| e.name == "c.py").unwrap();
        assert_eq!(ec.data(&tar), b"cccc");
    }

    #[test]
    fn replace_missing_member_errors() {
        let mut b = TarBuilder::new();
        b.append_file("a.py", b"aaaa").unwrap();
        let mut tar = b.finish();
        assert!(replace_file(&mut tar, "nope.py", b"x").is_err());
    }

    #[test]
    fn rejects_traversal() {
        let mut b = TarBuilder::new();
        b.append_file("../evil", b"x").unwrap();
        let tar = b.finish();
        let dst = tmpdir("trav");
        assert!(untar_to(&tar, &dst).is_err());
        std::fs::remove_dir_all(&dst).unwrap();
    }

    #[test]
    fn insert_and_remove_members() {
        let mut b = TarBuilder::new();
        b.append_file("a.py", b"aaaa").unwrap();
        b.append_file("b.py", b"bb").unwrap();
        let mut tar = b.finish();

        insert_file(&mut tar, "c.py", b"cc-new").unwrap();
        let r = TarReader::new(&tar).unwrap();
        assert_eq!(r.file_names(), vec!["a.py", "b.py", "c.py"]);
        assert_eq!(r.find("c.py").unwrap().data(&tar), b"cc-new");

        // Sorted insertion: a name ordering between existing members
        // lands in the middle, matching what a fresh build would archive.
        insert_file(&mut tar, "ab.py", b"between").unwrap();
        let r = TarReader::new(&tar).unwrap();
        assert_eq!(r.file_names(), vec!["a.py", "ab.py", "b.py", "c.py"]);
        remove_file(&mut tar, "ab.py").unwrap();

        // insert_file on an existing member degrades to replace.
        insert_file(&mut tar, "a.py", b"AAAA!").unwrap();
        let r = TarReader::new(&tar).unwrap();
        assert_eq!(r.find("a.py").unwrap().data(&tar), b"AAAA!");
        assert_eq!(r.file_names().len(), 3);

        remove_file(&mut tar, "b.py").unwrap();
        let r = TarReader::new(&tar).unwrap();
        assert_eq!(r.file_names(), vec!["a.py", "c.py"]);
        assert_eq!(tar.len() % BLOCK_SIZE, 0);
        assert!(remove_file(&mut tar, "b.py").is_err());
    }

    #[test]
    fn insert_into_empty_archive() {
        let mut tar = TarBuilder::new().finish();
        insert_file(&mut tar, "only.py", b"x").unwrap();
        let r = TarReader::new(&tar).unwrap();
        assert_eq!(r.file_names(), vec!["only.py"]);
    }

    #[test]
    fn replace_round_trip_property() {
        prop::check("tar replace == rebuild", 40, |g| {
            let n_files = g.len(1, 6);
            let mut b = TarBuilder::new();
            let mut contents = Vec::new();
            for i in 0..n_files {
                let data = g.vec_u8(0, 3000);
                b.append_file(&format!("f{}.py", i), &data).unwrap();
                contents.push(data);
            }
            let mut tar = b.finish();
            let target = g.below(n_files as u64) as usize;
            let new_data = g.vec_u8(0, 3000);
            replace_file(&mut tar, &format!("f{}.py", target), &new_data).unwrap();
            contents[target] = new_data;

            let r = TarReader::new(&tar).map_err(|e| e.to_string())?;
            for (i, want) in contents.iter().enumerate() {
                let e = r
                    .entries()
                    .into_iter()
                    .find(|e| e.name == format!("f{}.py", i))
                    .ok_or("missing member")?;
                if e.data(&tar) != &want[..] {
                    return Err(format!("member f{} corrupted", i));
                }
            }
            Ok(())
        });
    }
}
