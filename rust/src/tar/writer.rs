//! Deterministic in-memory tar writer.

use super::header::{Header, BLOCK_SIZE};
use crate::Result;

/// Builds a tar archive in memory. Call [`TarBuilder::finish`] to obtain
/// the archive bytes (including the two terminating zero blocks).
#[derive(Default)]
pub struct TarBuilder {
    buf: Vec<u8>,
}

impl TarBuilder {
    pub fn new() -> Self {
        TarBuilder { buf: Vec::new() }
    }

    /// Pre-allocate for an expected content size (perf: avoids regrowth
    /// while archiving large layers).
    pub fn with_capacity(bytes: usize) -> Self {
        TarBuilder {
            buf: Vec::with_capacity(bytes),
        }
    }

    /// Append a regular file member.
    pub fn append_file(&mut self, name: &str, data: &[u8]) -> Result<()> {
        let mut hdr = Header::for_file(name, data.len() as u64)?;
        hdr.finalize_checksum();
        self.buf.extend_from_slice(&hdr.to_bytes());
        self.buf.extend_from_slice(data);
        let pad = super::padded(data.len()) - data.len();
        self.buf.extend(std::iter::repeat(0u8).take(pad));
        Ok(())
    }

    /// Append a directory member.
    pub fn append_dir(&mut self, name: &str) -> Result<()> {
        let mut hdr = Header::for_dir(name)?;
        hdr.finalize_checksum();
        self.buf.extend_from_slice(&hdr.to_bytes());
        Ok(())
    }

    /// Current archive size (without the end-of-archive marker).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Terminate the archive (two zero blocks) and return the bytes.
    pub fn finish(mut self) -> Vec<u8> {
        self.buf.extend(std::iter::repeat(0u8).take(2 * BLOCK_SIZE));
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tar::TarReader;

    #[test]
    fn empty_archive_is_two_blocks() {
        let tar = TarBuilder::new().finish();
        assert_eq!(tar.len(), 2 * BLOCK_SIZE);
        assert!(TarReader::new(&tar).unwrap().entries().is_empty());
    }

    #[test]
    fn file_data_is_block_padded() {
        let mut b = TarBuilder::new();
        b.append_file("x.bin", &[9u8; 700]).unwrap();
        let tar = b.finish();
        // header + 2 data blocks + 2 eof blocks
        assert_eq!(tar.len(), BLOCK_SIZE * (1 + 2 + 2));
    }

    #[test]
    fn zero_length_file() {
        let mut b = TarBuilder::new();
        b.append_file("empty", b"").unwrap();
        let tar = b.finish();
        let r = TarReader::new(&tar).unwrap();
        let e = &r.entries()[0];
        assert_eq!(e.size, 0);
        assert!(e.data(&tar).is_empty());
    }
}
