//! ustar header block encoding/decoding.

use crate::{Error, Result};

/// Tar block size; headers are one block, file data is padded to blocks.
pub const BLOCK_SIZE: usize = 512;

/// Member types we support (layers only contain files and directories).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TypeFlag {
    Regular,
    Directory,
}

impl TypeFlag {
    fn to_byte(self) -> u8 {
        match self {
            TypeFlag::Regular => b'0',
            TypeFlag::Directory => b'5',
        }
    }

    fn from_byte(b: u8) -> Result<TypeFlag> {
        match b {
            b'0' | 0 => Ok(TypeFlag::Regular),
            b'5' => Ok(TypeFlag::Directory),
            other => Err(Error::Tar(format!("unsupported typeflag {:?}", other as char))),
        }
    }
}

/// A decoded ustar header.
#[derive(Clone, Debug)]
pub struct Header {
    pub name: String,
    pub mode: u32,
    pub size: u64,
    pub typeflag: TypeFlag,
    checksum: u32,
}

impl Header {
    /// Header for a regular file with normalized metadata (mode 0644,
    /// uid/gid 0, mtime 0 — archives must be deterministic).
    pub fn for_file(name: &str, size: u64) -> Result<Header> {
        if name.len() > 100 {
            // The 155-byte prefix field could extend this; our workloads
            // never need paths that long, so keep the format simple.
            return Err(Error::Tar(format!("member name too long: {name}")));
        }
        Ok(Header {
            name: name.to_string(),
            mode: 0o644,
            size,
            typeflag: TypeFlag::Regular,
            checksum: 0,
        })
    }

    /// Header for a directory.
    pub fn for_dir(name: &str) -> Result<Header> {
        let name = format!("{}/", name.trim_end_matches('/'));
        if name.len() > 100 {
            return Err(Error::Tar(format!("member name too long: {name}")));
        }
        Ok(Header {
            name,
            mode: 0o755,
            size: 0,
            typeflag: TypeFlag::Directory,
            checksum: 0,
        })
    }

    /// Compute and store the header checksum (must be called before
    /// `to_bytes`; done automatically by the writer).
    pub fn finalize_checksum(&mut self) {
        let mut bytes = self.encode(0);
        // Checksum is computed with the checksum field set to spaces.
        for b in &mut bytes[148..156] {
            *b = b' ';
        }
        let sum: u32 = bytes.iter().map(|&b| b as u32).sum();
        self.checksum = sum;
    }

    /// Serialize to a 512-byte block.
    pub fn to_bytes(&self) -> [u8; BLOCK_SIZE] {
        self.encode(self.checksum)
    }

    fn encode(&self, checksum: u32) -> [u8; BLOCK_SIZE] {
        let mut block = [0u8; BLOCK_SIZE];
        write_str(&mut block[0..100], &self.name);
        write_octal(&mut block[100..108], self.mode as u64);
        write_octal(&mut block[108..116], 0); // uid
        write_octal(&mut block[116..124], 0); // gid
        write_octal(&mut block[124..136], self.size);
        write_octal(&mut block[136..148], 0); // mtime
        write_checksum(&mut block[148..156], checksum);
        block[156] = self.typeflag.to_byte();
        // linkname: empty
        block[257..263].copy_from_slice(b"ustar\0");
        block[263..265].copy_from_slice(b"00");
        write_str(&mut block[265..297], "root"); // uname
        write_str(&mut block[297..329], "root"); // gname
        write_octal(&mut block[329..337], 0); // devmajor
        write_octal(&mut block[337..345], 0); // devminor
        block
    }

    /// Decode a header block. Returns `Ok(None)` for an all-zero block
    /// (end-of-archive marker).
    pub fn from_bytes(block: &[u8]) -> Result<Option<Header>> {
        if block.len() < BLOCK_SIZE {
            return Err(Error::Tar("truncated header block".into()));
        }
        if block[..BLOCK_SIZE].iter().all(|&b| b == 0) {
            return Ok(None);
        }
        let stored_sum = read_octal(&block[148..156])? as u32;
        let mut check = block[..BLOCK_SIZE].to_vec();
        for b in &mut check[148..156] {
            *b = b' ';
        }
        let actual: u32 = check.iter().map(|&b| b as u32).sum();
        if actual != stored_sum {
            return Err(Error::Tar(format!(
                "header checksum mismatch: stored {stored_sum}, computed {actual}"
            )));
        }
        Ok(Some(Header {
            name: read_str(&block[0..100]),
            mode: read_octal(&block[100..108])? as u32,
            size: read_octal(&block[124..136])?,
            typeflag: TypeFlag::from_byte(block[156])?,
            checksum: stored_sum,
        }))
    }
}

fn write_str(field: &mut [u8], s: &str) {
    let bytes = s.as_bytes();
    field[..bytes.len()].copy_from_slice(bytes);
}

/// NUL-terminated octal ASCII, as GNU tar writes it.
fn write_octal(field: &mut [u8], value: u64) {
    let width = field.len() - 1; // leave room for NUL
    let s = format!("{:0width$o}", value, width = width);
    field[..width].copy_from_slice(s.as_bytes());
    field[width] = 0;
}

/// Checksum field has its own quirky format: 6 octal digits, NUL, space.
fn write_checksum(field: &mut [u8], value: u32) {
    let s = format!("{:06o}", value);
    field[..6].copy_from_slice(s.as_bytes());
    field[6] = 0;
    field[7] = b' ';
}

fn read_str(field: &[u8]) -> String {
    let end = field.iter().position(|&b| b == 0).unwrap_or(field.len());
    String::from_utf8_lossy(&field[..end]).into_owned()
}

fn read_octal(field: &[u8]) -> Result<u64> {
    let s = read_str(field);
    let trimmed = s.trim_matches(|c: char| c == ' ' || c == '\0');
    if trimmed.is_empty() {
        return Ok(0);
    }
    u64::from_str_radix(trimmed, 8).map_err(|e| Error::Tar(format!("bad octal field {s:?}: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_round_trip() {
        let mut h = Header::for_file("dir/app.py", 12345).unwrap();
        h.finalize_checksum();
        let bytes = h.to_bytes();
        let back = Header::from_bytes(&bytes).unwrap().unwrap();
        assert_eq!(back.name, "dir/app.py");
        assert_eq!(back.size, 12345);
        assert_eq!(back.typeflag, TypeFlag::Regular);
    }

    #[test]
    fn dir_header_gets_trailing_slash() {
        let mut h = Header::for_dir("pkg").unwrap();
        h.finalize_checksum();
        let back = Header::from_bytes(&h.to_bytes()).unwrap().unwrap();
        assert_eq!(back.name, "pkg/");
        assert_eq!(back.typeflag, TypeFlag::Directory);
    }

    #[test]
    fn zero_block_is_eof() {
        assert!(Header::from_bytes(&[0u8; BLOCK_SIZE]).unwrap().is_none());
    }

    #[test]
    fn corrupt_checksum_rejected() {
        let mut h = Header::for_file("x", 1).unwrap();
        h.finalize_checksum();
        let mut bytes = h.to_bytes();
        bytes[0] ^= 0xff;
        assert!(Header::from_bytes(&bytes).is_err());
    }

    #[test]
    fn long_name_rejected() {
        let long = "a/".repeat(60);
        assert!(Header::for_file(&long, 0).is_err());
    }

    #[test]
    fn octal_fields() {
        let mut f = [0u8; 12];
        write_octal(&mut f, 0o777_777);
        assert_eq!(read_octal(&f).unwrap(), 0o777_777);
    }
}
