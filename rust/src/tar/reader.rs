//! Tar reader: parses headers and exposes member byte ranges.

use super::header::{Header, TypeFlag, BLOCK_SIZE};
use crate::{Error, Result};

/// A parsed archive member. Data is *not* copied — [`Entry::data`] slices
/// the original archive buffer, and the offsets are public because the
/// injection path patches archives in place.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub typeflag: TypeFlag,
    pub size: u64,
    /// Offset of the 512-byte header block within the archive.
    pub header_offset: usize,
    /// Offset of the first data byte within the archive.
    pub data_offset: usize,
}

impl Entry {
    /// The member's contents, sliced out of the archive buffer.
    pub fn data<'a>(&self, tar: &'a [u8]) -> &'a [u8] {
        &tar[self.data_offset..self.data_offset + self.size as usize]
    }
}

/// Parses a complete in-memory archive eagerly (layers are modest-sized;
/// eager parsing keeps the API simple and the offsets stable).
pub struct TarReader {
    entries: Vec<Entry>,
}

impl TarReader {
    pub fn new(tar: &[u8]) -> Result<TarReader> {
        if tar.len() % BLOCK_SIZE != 0 {
            return Err(Error::Tar(format!(
                "archive length {} not block-aligned",
                tar.len()
            )));
        }
        let mut entries = Vec::new();
        let mut off = 0;
        while off + BLOCK_SIZE <= tar.len() {
            match Header::from_bytes(&tar[off..off + BLOCK_SIZE])? {
                None => break, // zero block: end of archive
                Some(hdr) => {
                    let data_offset = off + BLOCK_SIZE;
                    let data_len = super::padded(hdr.size as usize);
                    if data_offset + data_len > tar.len() {
                        return Err(Error::Tar(format!(
                            "member {:?} data overruns archive",
                            hdr.name
                        )));
                    }
                    entries.push(Entry {
                        name: hdr.name.trim_end_matches('/').to_string(),
                        typeflag: hdr.typeflag,
                        size: hdr.size,
                        header_offset: off,
                        data_offset,
                    });
                    off = data_offset + data_len;
                }
            }
        }
        Ok(TarReader { entries })
    }

    /// All members, in archive order. Directory names have the trailing
    /// slash stripped.
    pub fn entries(&self) -> Vec<Entry> {
        self.entries.clone()
    }

    /// Find a regular-file member by name.
    pub fn find(&self, name: &str) -> Option<&Entry> {
        self.entries
            .iter()
            .find(|e| e.name == name && e.typeflag == TypeFlag::Regular)
    }

    /// Names of all regular files.
    pub fn file_names(&self) -> Vec<String> {
        self.entries
            .iter()
            .filter(|e| e.typeflag == TypeFlag::Regular)
            .map(|e| e.name.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tar::TarBuilder;

    #[test]
    fn parses_members_in_order() {
        let mut b = TarBuilder::new();
        b.append_dir("pkg").unwrap();
        b.append_file("pkg/a.py", b"aa").unwrap();
        b.append_file("b.py", &[1u8; 513]).unwrap();
        let tar = b.finish();
        let r = TarReader::new(&tar).unwrap();
        let names: Vec<_> = r.entries().iter().map(|e| e.name.clone()).collect();
        assert_eq!(names, vec!["pkg", "pkg/a.py", "b.py"]);
        assert_eq!(r.find("pkg/a.py").unwrap().size, 2);
        assert!(r.find("pkg").is_none()); // directories are not files
        assert_eq!(r.file_names(), vec!["pkg/a.py", "b.py"]);
    }

    #[test]
    fn rejects_unaligned() {
        assert!(TarReader::new(&[0u8; 100]).is_err());
    }

    #[test]
    fn rejects_overrun() {
        let mut b = TarBuilder::new();
        b.append_file("x", &[7u8; 2000]).unwrap();
        let tar = b.finish();
        // Chop the archive mid-data.
        assert!(TarReader::new(&tar[..BLOCK_SIZE * 2]).is_err());
    }

    #[test]
    fn data_slices_correct_bytes() {
        let mut b = TarBuilder::new();
        b.append_file("a", b"first").unwrap();
        b.append_file("b", b"second!").unwrap();
        let tar = b.finish();
        let r = TarReader::new(&tar).unwrap();
        assert_eq!(r.find("a").unwrap().data(&tar), b"first");
        assert_eq!(r.find("b").unwrap().data(&tar), b"second!");
    }
}
