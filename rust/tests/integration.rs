//! Cross-module integration tests, driven purely through the public API
//! (`layerjet::prelude` + daemon/coordinator/registry facades).

use layerjet::builder::{BuildOptions, CostModel};
use layerjet::coordinator::{BuildCoordinator, BuildRequest, BuildStrategy};
use layerjet::inject::{InjectMode, InjectOptions};
use layerjet::prelude::*;
use std::path::{Path, PathBuf};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lj-int-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn daemon(root: &Path) -> Daemon {
    let mut d = Daemon::new(root).unwrap();
    d.cost = CostModel::instant();
    d
}

fn write_ctx(dir: &Path, dockerfile: &str, files: &[(&str, &str)]) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join("Dockerfile"), dockerfile).unwrap();
    for (p, c) in files {
        let path = dir.join(p);
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, c).unwrap();
    }
}

const DF: &str = "FROM python:alpine\nCOPY . /root/\nWORKDIR /root\nCMD [\"python\", \"main.py\"]\n";

/// Build → inject → save → load on a second machine → push → pull on a
/// third: the full image lifecycle with an injected revision inside it.
#[test]
fn full_lifecycle_with_injection() {
    let root = tmp("lifecycle");
    let machine_a = daemon(&root.join("a"));
    let machine_b = daemon(&root.join("b"));
    let machine_c = daemon(&root.join("c"));
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    let ctx = root.join("ctx");
    write_ctx(&ctx, DF, &[("main.py", "print('v1')\n")]);

    machine_a.build(&ctx, "svc:v1").unwrap();
    std::fs::write(ctx.join("main.py"), "print('v1')\nprint('v2')\n").unwrap();
    let report = machine_a
        .inject_with(
            &ctx,
            "svc:v1",
            "svc:v2",
            &InjectOptions {
                clone_for_redeploy: true,
                cost: CostModel::instant(),
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(report.patched.len(), 1);
    assert!(report.patched[0].cloned_as.is_some());

    // Bundle to machine B.
    let bundle = machine_a.save("svc:v2").unwrap();
    let loaded = machine_b.load(&bundle).unwrap();
    assert_eq!(loaded.to_string(), "svc:v2");
    assert!(machine_b.verify_image("svc:v2").unwrap());

    // Registry to machine C.
    machine_a.push("svc:v2", &remote).unwrap();
    machine_c.pull("svc:v2", &remote).unwrap();
    assert!(machine_c.verify_image("svc:v2").unwrap());

    // All three machines hold identical layer content.
    let (_, img_a) = machine_a.image("svc:v2").unwrap();
    for lid in &img_a.layer_ids {
        assert_eq!(
            machine_a.layers.read_tar(lid).unwrap(),
            machine_b.layers.read_tar(lid).unwrap()
        );
        assert_eq!(
            machine_a.layers.read_tar(lid).unwrap(),
            machine_c.layers.read_tar(lid).unwrap()
        );
    }
    std::fs::remove_dir_all(&root).unwrap();
}

/// Explicit and implicit decomposition land on identical image state.
#[test]
fn explicit_implicit_equivalence_via_daemon() {
    let root = tmp("modes");
    let ctx = root.join("ctx");
    write_ctx(&ctx, DF, &[("main.py", "print('v1')\n"), ("util.py", "u = 1\n")]);

    let run = |mode: InjectMode, sub: &str| -> Vec<Digest> {
        let d = daemon(&root.join(sub));
        d.build(&ctx, "m:v1").unwrap();
        std::fs::write(ctx.join("util.py"), "u = 2\nv = 3\n").unwrap();
        d.inject_with(
            &ctx,
            "m:v1",
            "m:v1",
            &InjectOptions {
                mode,
                cost: CostModel::instant(),
                ..Default::default()
            },
        )
        .unwrap();
        std::fs::write(ctx.join("util.py"), "u = 1\n").unwrap(); // restore
        let (_, img) = d.image("m:v1").unwrap();
        img.diff_ids
    };

    let implicit = run(InjectMode::Implicit, "imp");
    let explicit = run(InjectMode::Explicit, "exp");
    assert_eq!(implicit, explicit, "both modes must yield identical checksums");
    std::fs::remove_dir_all(&root).unwrap();
}

/// A long revision chain: inject 10 times, then prove a from-scratch
/// build of the final context produces identical layer content.
#[test]
fn ten_revision_chain_converges_with_fresh_build() {
    let root = tmp("chain");
    let ctx = root.join("ctx");
    write_ctx(&ctx, DF, &[("main.py", "print('v0')\n")]);
    let incremental = daemon(&root.join("incremental"));
    incremental.build(&ctx, "app:latest").unwrap();
    for rev in 1..=10 {
        let mut text = std::fs::read_to_string(ctx.join("main.py")).unwrap();
        text.push_str(&format!("print('rev {rev}')\n"));
        std::fs::write(ctx.join("main.py"), text).unwrap();
        incremental.inject(&ctx, "app:latest", "app:latest").unwrap();
    }
    assert!(incremental.verify_image("app:latest").unwrap());

    let fresh = daemon(&root.join("fresh"));
    fresh.build(&ctx, "app:latest").unwrap();

    let (_, img_i) = incremental.image("app:latest").unwrap();
    let (_, img_f) = fresh.image("app:latest").unwrap();
    // Same permanent ids, same final checksums.
    assert_eq!(img_i.layer_ids, img_f.layer_ids);
    assert_eq!(img_i.diff_ids, img_f.diff_ids);
    std::fs::remove_dir_all(&root).unwrap();
}

/// The coordinator end-to-end with a mixed strategy batch.
#[test]
fn coordinator_mixed_strategies() {
    let root = tmp("coord");
    let ctx = root.join("ctx");
    write_ctx(&ctx, DF, &[("main.py", "print('v1')\n")]);

    let mut coordinator = BuildCoordinator::new(&root.join("farm"), 2);
    coordinator.cost = CostModel::instant();

    // Cold build on both workers (so either can serve later requests).
    let cold: Vec<BuildRequest> = (0..2)
        .map(|i| BuildRequest {
            id: i,
            project: ctx.clone(),
            tag: "app:latest".into(),
            strategy: BuildStrategy::DockerRebuild,
        })
        .collect();
    let (outcomes, _) = coordinator.run(cold).unwrap();
    assert!(outcomes.iter().all(|o| o.ok));

    std::fs::write(ctx.join("main.py"), "print('v1')\nprint('v2')\n").unwrap();
    let (outcomes, metrics) = coordinator
        .run(vec![
            BuildRequest {
                id: 10,
                project: ctx.clone(),
                tag: "app:latest".into(),
                strategy: BuildStrategy::Auto,
            },
            BuildRequest {
                id: 11,
                project: ctx.clone(),
                tag: "app:latest".into(),
                strategy: BuildStrategy::DockerRebuild,
            },
        ])
        .unwrap();
    assert_eq!(metrics.completed, 2);
    assert!(outcomes.iter().all(|o| o.ok), "{outcomes:?}");
    std::fs::remove_dir_all(&root).unwrap();
}

/// no-cache rebuild after an injection reproduces the same content
/// (the injected state is not a divergent fork).
#[test]
fn no_cache_rebuild_matches_injected_state() {
    let root = tmp("nocache");
    let ctx = root.join("ctx");
    write_ctx(&ctx, DF, &[("main.py", "print('v1')\n")]);
    let d = daemon(&root.join("d"));
    d.build(&ctx, "app:latest").unwrap();
    std::fs::write(ctx.join("main.py"), "print('v1')\nprint('more')\n").unwrap();
    d.inject(&ctx, "app:latest", "app:latest").unwrap();
    let (_, injected) = d.image("app:latest").unwrap();

    let rebuilt = d
        .build_with(
            &ctx,
            "app:latest",
            &BuildOptions {
                no_cache: true,
                cost: CostModel::instant(),
                jobs: 1,
            },
        )
        .unwrap();
    let rebuilt_img = d.images.get(&rebuilt.image_id).unwrap();
    assert_eq!(injected.diff_ids, rebuilt_img.diff_ids);
    std::fs::remove_dir_all(&root).unwrap();
}

/// Multi-layer targeted injection — the paper's §V future work
/// ("we will proceed to investigate the mechanism of performing
/// multi-layer injection"): two independent COPY layers change in the
/// same revision and a single inject patches both, bypassing both
/// checksums.
#[test]
fn multi_layer_injection() {
    let root = tmp("multilayer");
    let ctx = root.join("ctx");
    write_ctx(
        &ctx,
        "FROM python:alpine\nCOPY app /srv/app/\nCOPY conf /etc/conf/\nCMD [\"python\", \"/srv/app/main.py\"]\n",
        &[
            ("app/main.py", "print('v1')\n"),
            ("conf/settings.ini", "mode=dev\n"),
        ],
    );
    let d = daemon(&root.join("d"));
    d.build(&ctx, "svc:latest").unwrap();

    // Change BOTH layers in one revision.
    std::fs::write(ctx.join("app/main.py"), "print('v2')\n").unwrap();
    std::fs::write(ctx.join("conf/settings.ini"), "mode=prod\n").unwrap();
    let report = d.inject(&ctx, "svc:latest", "svc:latest").unwrap();
    assert_eq!(report.patched.len(), 2, "both layers patched in one pass");
    assert!(report.digests_rewritten >= 2);
    assert!(d.verify_image("svc:latest").unwrap());

    // Both layers carry the new content; a fresh build agrees byte-for-byte.
    let fresh = daemon(&root.join("fresh"));
    fresh.build(&ctx, "svc:latest").unwrap();
    let (_, a) = d.image("svc:latest").unwrap();
    let (_, b) = fresh.image("svc:latest").unwrap();
    assert_eq!(a.diff_ids, b.diff_ids);
    std::fs::remove_dir_all(&root).unwrap();
}

/// The CLI binary works end to end (build → inject → verify → history).
#[test]
fn cli_binary_smoke() {
    let root = tmp("cli");
    let ctx = root.join("ctx");
    write_ctx(&ctx, "FROM python:alpine\nCOPY main.py main.py\nCMD [\"python\", \"main.py\"]\n", &[("main.py", "print('v1')\n")]);
    let bin = env!("CARGO_BIN_EXE_layerjet");
    let run = |args: &[&str]| {
        let out = std::process::Command::new(bin)
            .arg("--root")
            .arg(root.join("state"))
            .args(args)
            .output()
            .expect("spawn layerjet");
        assert!(
            out.status.success(),
            "layerjet {:?} failed:\n{}\n{}",
            args,
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).into_owned()
    };
    let ctx_str = ctx.to_str().unwrap();
    let transcript = run(&["build", "-t", "cli:latest", ctx_str]);
    assert!(transcript.contains("Step 1/3 : FROM python:alpine"));
    std::fs::write(ctx.join("main.py"), "print('v1')\nprint('v2')\n").unwrap();
    let inj = run(&["inject", "-t", "cli:latest", ctx_str]);
    assert!(inj.contains("injection complete"), "{inj}");
    let verify = run(&["verify", "cli:latest"]);
    assert!(verify.contains("OK"), "{verify}");
    let hist = run(&["history", "cli:latest"]);
    assert!(hist.contains("COPY main.py main.py"), "{hist}");
    std::fs::remove_dir_all(&root).unwrap();
}
