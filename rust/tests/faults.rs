//! Fault-matrix acceptance tests: every registered durability boundary
//! is killed mid-flight, the process "restarts" (stores reopen and run
//! their implicit recovery sweeps), the same work is re-run, and the
//! final local + remote trees must be bit-identical to a never-faulted
//! run with zero orphaned temp files, staging/journal leftovers, or
//! stale lease records.
//!
//! All plans are scoped to the test's own temp root so parallel test
//! binaries cannot trip each other's specs; `fault::install` additionally
//! serializes installers within this process.

use layerjet::fault::{self, FaultMode, FaultPlan};
use layerjet::prelude::*;
use layerjet::registry::{LeaseConfig, PullOptions, PushOptions};
use layerjet::util::prng::Prng;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lj-faults-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn daemon(root: &Path) -> layerjet::Result<Daemon> {
    let mut daemon = Daemon::new(root)?;
    daemon.cost = CostModel::instant();
    Ok(daemon)
}

/// A three-layer project with a RUN step and a chunk-spanning COPY asset,
/// so the scenario arrives at every fault site more than once.
fn write_project(dir: &Path) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("Dockerfile"),
        "FROM python:alpine\nCOPY . /srv/\nRUN pip install flask\nCMD [\"python\", \"app.py\"]\n",
    )
    .unwrap();
    let mut asset = vec![0u8; 48 * 1024];
    Prng::new(0xfa17).fill_bytes(&mut asset);
    std::fs::write(dir.join("asset.bin"), &asset).unwrap();
    std::fs::write(dir.join("app.py"), "print('faulted')\n").unwrap();
}

/// Every file under `root`, relative path -> bytes, skipping the
/// scan-cache (its file names key on the absolute context path, so they
/// differ between the reference root and each matrix case root) and the
/// lease directory (its `seq`/`fence` counters advance differently on a
/// faulted-then-recovered run than on the reference run; lease hygiene
/// is asserted separately by [`assert_no_orphans`]).
fn snapshot(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(dir: &Path, prefix: &str, out: &mut BTreeMap<String, Vec<u8>>) {
        let mut entries: Vec<_> = std::fs::read_dir(dir).unwrap().map(|e| e.unwrap()).collect();
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let name = e.file_name().to_string_lossy().into_owned();
            let rel = if prefix.is_empty() { name.clone() } else { format!("{prefix}/{name}") };
            if e.file_type().unwrap().is_dir() {
                if name == "scan-cache" || name == "leases" {
                    continue;
                }
                walk(&e.path(), &rel, out);
            } else {
                out.insert(rel, std::fs::read(e.path()).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, "", &mut out);
    out
}

/// No orphaned atomic-write temp files, no push-journal entries, no
/// pull-staging chunks anywhere under `root`, and no lease directory
/// holding anything besides its `seq`/`fence` counters (a surviving
/// grant record, guard lockfile, or temp file is a stale lease).
fn assert_no_orphans(root: &Path, context: &str) {
    for (rel, _) in snapshot(root) {
        assert!(!rel.contains(".tmp-"), "{context}: orphaned temp file {rel}");
        assert!(!rel.contains("push-journal/"), "{context}: leftover journal entry {rel}");
        assert!(!rel.contains("pull-staging/"), "{context}: leftover staged chunk {rel}");
    }
    fn check_leases(dir: &Path, context: &str) {
        for e in std::fs::read_dir(dir).unwrap().map(|e| e.unwrap()) {
            let name = e.file_name().to_string_lossy().into_owned();
            if e.file_type().unwrap().is_dir() {
                if name == "leases" {
                    for f in std::fs::read_dir(e.path()).unwrap().map(|f| f.unwrap()) {
                        let fname = f.file_name().to_string_lossy().into_owned();
                        assert!(
                            fname == "seq" || fname == "fence",
                            "{context}: stale lease file leases/{fname}"
                        );
                    }
                } else {
                    check_leases(&e.path(), context);
                }
            }
        }
    }
    check_leases(root, context);
}

/// The full durability scenario under one root: build locally, push to a
/// registry in `<root>/remote`, re-shard the pool to two backends (the
/// `registry.shard.migrate` site), pull into a second store in
/// `<root>/prod` through a persistent pull cache at `<root>/edge-cache`
/// (the `registry.cache.{put,get}` sites — the cache dir sits outside
/// the three bit-compared trees because its contents legitimately differ
/// between a faulted-then-recovered run and the reference), then run the
/// maintenance pass (scrub marker, scrub, repair, gc) so the exclusive-lease
/// sites are inside the faulted window. Reopening the daemons/registry
/// on every call is the "restart" — each open runs its implicit recovery
/// sweep (and `PullCache::open` sweeps its own temp files). The lease
/// ttl is zero so a record stranded by an injected crash is reclaimed at
/// the next open instead of stalling the recovery re-run for a
/// wall-clock ttl.
fn run_scenario(root: &Path) -> layerjet::Result<()> {
    let proj = root.join("proj");
    if !proj.exists() {
        write_project(&proj);
    }
    let dev = daemon(&root.join("dev"))?;
    dev.build(&proj, "app:v1")?;
    let remote = RemoteRegistry::open_with(
        &root.join("remote"),
        LeaseConfig { ttl: std::time::Duration::ZERO, ..Default::default() },
    )?;
    dev.push_with("app:v1", &remote, &PushOptions { jobs: 1, ..Default::default() })?;
    // Split the pool across two consistent-hash backends at replica
    // factor 2, so every later chunk write fans out to both replicas
    // (`registry.backend.write`) and every pull read routes through the
    // failover path (`registry.backend.read`). Idempotent: the recovery
    // re-run converges a half-migrated pool on the same bit-identical
    // layout the reference run committed.
    remote.shard_to_with(2, 2)?;
    let cache = layerjet::registry::PullCache::open_default(&root.join("edge-cache"))?;
    let prod = daemon(&root.join("prod"))?;
    prod.pull_with(
        "app:v1",
        &remote,
        &PullOptions { jobs: 1, pull_cache: Some(cache), ..Default::default() },
    )?;
    assert!(prod.verify_image("app:v1")?, "pulled image must verify");
    // Maintenance coda: on a clean tree this is a no-op (the marker is
    // consumed by scrub, every replica set is already full so repair
    // copies nothing, everything is tagged so gc drops nothing), but it
    // routes the scenario through the scrub-marker write, the
    // anti-entropy walk, and both exclusive-lease acquire/release paths
    // so the matrix covers them.
    remote.schedule_scrub()?;
    remote.scrub()?;
    remote.repair()?;
    remote.gc()?;
    Ok(())
}

/// The capstone: for every registered fault site, inject a fatal fault
/// at the first, middle, and last arrival, "restart", re-run, and assert
/// the surviving state is bit-identical to a never-faulted run.
#[test]
fn fault_matrix_recovers_bit_identical_at_every_site() {
    // Reference run: never faulted.
    let reference = tmp("mx-ref");
    run_scenario(&reference).expect("the fault-free scenario must succeed");
    let want_dev = snapshot(&reference.join("dev"));
    let want_remote = snapshot(&reference.join("remote"));
    let want_prod = snapshot(&reference.join("prod"));

    // Probe run: count how often the scenario arrives at each site.
    let probe = tmp("mx-probe");
    let guard = fault::install(FaultPlan::observe().scoped(&probe));
    run_scenario(&probe).expect("the observe plan must inject nothing");
    let counts = guard.counts();
    drop(guard);
    let _ = std::fs::remove_dir_all(&probe);
    for &site in fault::SITES {
        assert!(
            counts.get(site).copied().unwrap_or(0) > 0,
            "scenario never arrives at registered site {site}; the matrix cannot cover it"
        );
    }

    let mut cases = 0usize;
    for &site in fault::SITES {
        let hits = counts[site];
        let mut ks = vec![0, hits / 2, hits - 1];
        ks.dedup();
        for (i, &k) in ks.iter().enumerate() {
            // Alternate the fatal flavours: a clean mid-operation crash
            // and a torn write that strands a partial temp file.
            let mode = if i == 1 { FaultMode::Torn(7) } else { FaultMode::Crash };
            let root = tmp(&format!("mx-{}-{}", site.replace('.', "-"), k));
            let guard = fault::install(FaultPlan::fail_at(site, k, mode).scoped(&root));
            let faulted = run_scenario(&root);
            drop(guard);
            assert!(
                faulted.is_err(),
                "fatal fault at {site} hit {k} ({mode:?}) must surface as an error"
            );

            // Restart: re-running reopens every store, which sweeps
            // orphans and resumes journals/staging; the second pass must
            // complete and converge on the reference state.
            run_scenario(&root).unwrap_or_else(|e| {
                panic!("recovery re-run after fault at {site} hit {k} failed: {e:?}")
            });
            let ctx = format!("{site} hit {k} ({mode:?})");
            assert_eq!(snapshot(&root.join("dev")), want_dev, "dev store diverged after {ctx}");
            assert_eq!(
                snapshot(&root.join("remote")),
                want_remote,
                "remote tree diverged after {ctx}"
            );
            assert_eq!(snapshot(&root.join("prod")), want_prod, "prod store diverged after {ctx}");
            assert_no_orphans(&root, &ctx);
            cases += 1;
            let _ = std::fs::remove_dir_all(&root);
        }
    }
    assert!(cases >= fault::SITES.len(), "matrix must cover every site at least once");
    let _ = std::fs::remove_dir_all(&reference);
}

/// Transient faults never surface: one injected error at a chunk write,
/// a chunk read, and a build step is absorbed by the retry policy, the
/// scenario succeeds first try, and the retries are visible in the
/// push/pull accounting.
#[test]
fn transient_faults_are_absorbed_and_accounted() {
    let root = tmp("transient");
    let proj = root.join("proj");
    write_project(&proj);
    let plan = FaultPlan::fail_at("registry.pool.put", 1, FaultMode::ErrOnce)
        .and("registry.pool.get", 1, FaultMode::ErrOnce)
        .and("builder.step", 0, FaultMode::ErrOnce)
        .scoped(&root);
    let guard = fault::install(plan);

    let dev = daemon(&root.join("dev")).unwrap();
    dev.build(&proj, "app:v1").expect("one transient step fault must be retried away");
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    let push = dev
        .push_with("app:v1", &remote, &PushOptions { jobs: 1, ..Default::default() })
        .expect("one transient chunk-write fault must be retried away");
    assert!(push.retries >= 1, "absorbed push fault must be accounted: {push:?}");
    assert_eq!(push.layers_degraded, 0, "a single transient error must not demote the layer");

    let prod = daemon(&root.join("prod")).unwrap();
    let pull = prod
        .pull_with("app:v1", &remote, &PullOptions { jobs: 1, ..Default::default() })
        .expect("one transient chunk-read fault must be retried away");
    drop(guard);
    assert!(pull.retries >= 1, "absorbed pull fault must be accounted: {pull:?}");
    assert_eq!(pull.layers_degraded, 0, "a single transient error must not demote the layer");
    assert!(prod.verify_image("app:v1").unwrap());
    assert_no_orphans(&root, "transient absorption");
    std::fs::remove_dir_all(&root).unwrap();
}

/// A step that fails transiently under the fleet scheduler is retried in
/// place — the request still completes, no single-flight follower is
/// poisoned, and the retries surface in the coordinator metrics.
#[test]
fn scheduler_retries_transient_step_faults_without_failing_requests() {
    let root = tmp("sched");
    let proj = root.join("proj");
    write_project(&proj);
    let guard = fault::install(FaultPlan::fail_at("builder.step", 1, FaultMode::ErrN(2)).scoped(&root));

    let mut coordinator = BuildCoordinator::new(&root.join("farm"), 2);
    coordinator.cost = CostModel::instant();
    coordinator.jobs = 2;
    let requests = vec![
        BuildRequest {
            id: 1,
            project: proj.clone(),
            tag: "app:v1".into(),
            strategy: BuildStrategy::DockerRebuild,
        },
        BuildRequest {
            id: 2,
            project: proj.clone(),
            tag: "app:v1".into(),
            strategy: BuildStrategy::DockerRebuild,
        },
    ];
    let (outcomes, metrics) = coordinator.run(requests).unwrap();
    drop(guard);
    assert!(
        outcomes.iter().all(|o| o.ok),
        "transient step faults must not fail any request: {outcomes:?}"
    );
    assert!(
        metrics.steps_retried >= 2,
        "both injected step errors must be absorbed and counted: {}",
        metrics.summary()
    );
    assert!(metrics.summary().contains("retried"), "summary must surface retry accounting");
    std::fs::remove_dir_all(&root).unwrap();
}
