//! Parallelism invariants: the data-parallel hashing engine and the
//! multi-job build engine must be *indistinguishable* from their
//! sequential baselines — identical digests, identical image ids,
//! identical layer bytes.

use layerjet::builder::{BuildOptions, CostModel};
use layerjet::daemon::Daemon;
use layerjet::hash::{ChunkDigest, HashEngine, NativeEngine, ParallelEngine, CHUNK_SIZE};
use layerjet::util::prop;
use std::path::{Path, PathBuf};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lj-par-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn write_ctx(dir: &Path, dockerfile: &str, files: &[(&str, &str)]) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join("Dockerfile"), dockerfile).unwrap();
    for (p, c) in files {
        let path = dir.join(p);
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, c).unwrap();
    }
}

/// Property: for random batch shapes — empty batches, one chunk, more
/// chunks than threads, short tail chunks — the parallel engine's
/// digests are bit-identical to the native engine's.
#[test]
fn parallel_engine_equals_native_on_random_batch_shapes() {
    prop::check("ParallelEngine == NativeEngine (batch shapes)", 40, |g| {
        let threads = 1 + g.below(8) as usize;
        // Bias the shape mix toward the interesting regimes.
        let n = match g.below(4) {
            0 => 0,
            1 => 1,
            2 => threads + g.len(1, 32),        // more chunks than threads
            _ => g.len(2, 3 * threads.max(2)),  // around the thread count
        };
        let chunks: Vec<Vec<u8>> = (0..n)
            .map(|i| {
                if i == n - 1 {
                    g.vec_u8(0, 37) // short tail chunk
                } else {
                    g.vec_u8(0, CHUNK_SIZE)
                }
            })
            .collect();
        let refs: Vec<&[u8]> = chunks.iter().map(|c| c.as_slice()).collect();
        let native = NativeEngine::new().hash_chunks(&refs);
        let parallel = ParallelEngine::new(threads).hash_chunks(&refs);
        if native == parallel {
            Ok(())
        } else {
            Err(format!("digests diverged: threads={threads} n={n}"))
        }
    });
}

/// Chunk-digest roots agree through the wrapper on batches large enough
/// to actually engage the thread pool.
#[test]
fn parallel_engine_roots_match_on_large_buffers() {
    let data: Vec<u8> = (0..CHUNK_SIZE * 300 + 1234).map(|i| (i % 241) as u8).collect();
    let native = ChunkDigest::compute(&data, &NativeEngine::new());
    for threads in [2, 4, 8] {
        assert_eq!(
            native,
            ChunkDigest::compute(&data, &ParallelEngine::new(threads)),
            "threads={threads}"
        );
    }
}

/// End-to-end: a jobs=4 build produces byte-identical image state to a
/// jobs=1 build of the same context.
#[test]
fn jobs4_build_is_byte_identical_to_jobs1() {
    let root = tmp("jobs");
    let df = "FROM python:alpine\n\
              COPY . /app/\n\
              RUN pip install alpha beta\n\
              RUN apt update && apt install curl -y\n\
              WORKDIR /app\n\
              CMD [\"python\", \"main.py\"]\n";
    let build = |jobs: usize, sub: &str| {
        let daemon_root = root.join(sub);
        let ctx = root.join(format!("{sub}-ctx"));
        write_ctx(&ctx, df, &[("main.py", "print('v1')\n"), ("util.py", "u = 1\n")]);
        let mut daemon = Daemon::new(&daemon_root).unwrap();
        daemon.cost = CostModel::instant();
        let report = daemon
            .build_with(
                &ctx,
                "par:latest",
                &BuildOptions {
                    no_cache: false,
                    cost: CostModel::instant(),
                    jobs,
                },
            )
            .unwrap();
        let (_, img) = daemon.image("par:latest").unwrap();
        let tars: Vec<Vec<u8>> = img
            .layer_ids
            .iter()
            .map(|l| daemon.layers.read_tar(l).unwrap())
            .collect();
        assert!(daemon.verify_image("par:latest").unwrap());
        (report.image_id, img.layer_ids.clone(), img.diff_ids.clone(), tars)
    };

    let (id1, layers1, diffs1, tars1) = build(1, "seq");
    let (id4, layers4, diffs4, tars4) = build(4, "par");
    assert_eq!(id1, id4, "image ids must match");
    assert_eq!(layers1, layers4);
    assert_eq!(diffs1, diffs4);
    assert_eq!(tars1, tars4, "layer tars must be byte-identical");
    std::fs::remove_dir_all(&root).unwrap();
}

/// A daemon running the parallel hashing engine interoperates with a
/// native-engine daemon: same builds, same image ids, and injection
/// stays integrity-clean.
#[test]
fn parallel_hashing_daemon_matches_native_daemon() {
    let root = tmp("engine");
    let ctx = root.join("ctx");
    write_ctx(
        &ctx,
        "FROM python:alpine\nCOPY . /root/\nCMD [\"python\", \"main.py\"]\n",
        &[("main.py", "print('v1')\n"), ("assets.bin", "0123456789")],
    );

    let mut native = Daemon::new(&root.join("native")).unwrap();
    native.cost = CostModel::instant();
    let mut parallel = Daemon::with_parallel_hashing(&root.join("parallel"), 4).unwrap();
    parallel.cost = CostModel::instant();

    let rn = native.build(&ctx, "app:v1").unwrap();
    let rp = parallel.build(&ctx, "app:v1").unwrap();
    assert_eq!(rn.image_id, rp.image_id);

    std::fs::write(ctx.join("main.py"), "print('v1')\nprint('v2')\n").unwrap();
    let inj = parallel.inject(&ctx, "app:v1", "app:v2").unwrap();
    assert_eq!(inj.patched.len(), 1);
    assert!(parallel.verify_image("app:v2").unwrap());

    // The native daemon reaches the same state by rebuilding.
    native.build(&ctx, "app:v2").unwrap();
    let (_, img_n) = native.image("app:v2").unwrap();
    let (_, img_p) = parallel.image("app:v2").unwrap();
    assert_eq!(img_n.diff_ids, img_p.diff_ids);
    std::fs::remove_dir_all(&root).unwrap();
}
