//! Property-based invariants over the public API (via the in-crate mini
//! property harness — the environment has no proptest).
//!
//! The central invariant, checked under randomized projects and edit
//! sequences: **injection is a shortcut, not a fork** — after any valid
//! sequence of content edits, the injected image is byte-equivalent to a
//! freshly built image of the same context, and always passes Docker's
//! integrity test.

use layerjet::builder::CostModel;
use layerjet::daemon::Daemon;
use layerjet::hash::{ChunkDigest, Digest, NativeEngine};
use layerjet::util::prop::{check, Gen};
use std::path::{Path, PathBuf};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lj-prop-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn daemon(root: &Path) -> Daemon {
    let mut d = Daemon::new(root).unwrap();
    d.cost = CostModel::instant();
    d
}

/// Random small python-ish project: a Dockerfile plus 1-5 source files.
fn gen_project(g: &mut Gen, dir: &Path) -> Vec<String> {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("Dockerfile"),
        "FROM python:alpine\nCOPY . /app/\nCMD [\"python\", \"app/main.py\"]\n",
    )
    .unwrap();
    let n = g.len(1, 5);
    let mut files = Vec::new();
    for i in 0..n {
        let name = format!("src{i}.py");
        let body: String = (0..g.len(1, 30))
            .map(|j| format!("x_{j} = {}\n", g.below(1000)))
            .collect();
        std::fs::write(dir.join(&name), body).unwrap();
        files.push(name);
    }
    std::fs::write(dir.join("main.py"), "print('main')\n").unwrap();
    files.push("main.py".into());
    files
}

/// Apply a random edit to the project; returns false if it was a no-op.
fn gen_edit(g: &mut Gen, dir: &Path, files: &mut Vec<String>) -> bool {
    match g.below(4) {
        0 => {
            // Append to an existing file.
            let f = files[g.below(files.len() as u64) as usize].clone();
            let mut text = std::fs::read_to_string(dir.join(&f)).unwrap();
            text.push_str(&format!("appended_{} = {}\n", g.below(100), g.below(100)));
            std::fs::write(dir.join(&f), text).unwrap();
            true
        }
        1 => {
            // Rewrite a file completely (possibly different size class).
            let f = files[g.below(files.len() as u64) as usize].clone();
            let body: String = (0..g.len(0, 60))
                .map(|j| format!("y_{j} = {}\n", g.below(1000)))
                .collect();
            std::fs::write(dir.join(&f), format!("# rewritten\n{body}")).unwrap();
            true
        }
        2 => {
            // Add a new file.
            let name = format!("new{}.py", g.below(1_000_000));
            std::fs::write(dir.join(&name), format!("z = {}\n", g.below(10))).unwrap();
            files.push(name);
            true
        }
        _ => {
            // Remove a file (keep at least main.py + one source).
            if files.len() > 2 {
                let idx = g.below((files.len() - 1) as u64) as usize; // never main.py (last)
                let f = files.remove(idx);
                std::fs::remove_file(dir.join(f)).unwrap();
                true
            } else {
                false
            }
        }
    }
}

#[test]
fn inject_equals_rebuild_under_random_edit_sequences() {
    let root = tmp("equiv");
    let mut case = 0u64;
    check("inject == rebuild (random projects + edits)", 12, |g| {
        case += 1;
        let case_dir = root.join(format!("case{case}"));
        let ctx = case_dir.join("ctx");
        let mut files = gen_project(g, &ctx);
        let d_inject = daemon(&case_dir.join("inject"));
        let d_build = daemon(&case_dir.join("build"));
        d_inject.build(&ctx, "p:latest").map_err(|e| e.to_string())?;

        let edits = g.len(1, 4);
        for _ in 0..edits {
            if !gen_edit(g, &ctx, &mut files) {
                continue;
            }
            d_inject
                .inject(&ctx, "p:latest", "p:latest")
                .map_err(|e| format!("inject: {e}"))?;
        }
        if !d_inject.verify_image("p:latest").map_err(|e| e.to_string())? {
            return Err("injected image failed integrity".into());
        }
        d_build.build(&ctx, "p:latest").map_err(|e| e.to_string())?;
        let (_, img_i) = d_inject.image("p:latest").map_err(|e| e.to_string())?;
        let (_, img_b) = d_build.image("p:latest").map_err(|e| e.to_string())?;
        if img_i.diff_ids != img_b.diff_ids {
            return Err(format!(
                "diverged after {edits} edit(s): {:?} vs {:?}",
                img_i.diff_ids, img_b.diff_ids
            ));
        }
        let _ = std::fs::remove_dir_all(&case_dir);
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn save_load_roundtrip_random_projects() {
    let root = tmp("bundle");
    let mut case = 0u64;
    check("save/load round trip", 10, |g| {
        case += 1;
        let case_dir = root.join(format!("case{case}"));
        let ctx = case_dir.join("ctx");
        gen_project(g, &ctx);
        let a = daemon(&case_dir.join("a"));
        let b = daemon(&case_dir.join("b"));
        a.build(&ctx, "p:latest").map_err(|e| e.to_string())?;
        let bundle = a.save("p:latest").map_err(|e| e.to_string())?;
        b.load(&bundle).map_err(|e| e.to_string())?;
        if !b.verify_image("p:latest").map_err(|e| e.to_string())? {
            return Err("loaded image failed integrity".into());
        }
        let (ia, img_a) = a.image("p:latest").map_err(|e| e.to_string())?;
        let (ib, _) = b.image("p:latest").map_err(|e| e.to_string())?;
        if ia != ib {
            return Err("image ids differ after round trip".into());
        }
        for lid in &img_a.layer_ids {
            if a.layers.read_tar(lid).map_err(|e| e.to_string())?
                != b.layers.read_tar(lid).map_err(|e| e.to_string())?
            {
                return Err(format!("layer {} differs", lid.short()));
            }
        }
        let _ = std::fs::remove_dir_all(&case_dir);
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn build_is_deterministic_across_daemons() {
    let root = tmp("det");
    let mut case = 0u64;
    check("same context => same image id on independent daemons", 8, |g| {
        case += 1;
        let case_dir = root.join(format!("case{case}"));
        let ctx = case_dir.join("ctx");
        gen_project(g, &ctx);
        let a = daemon(&case_dir.join("a"));
        let b = daemon(&case_dir.join("b"));
        let ra = a.build(&ctx, "p:latest").map_err(|e| e.to_string())?;
        let rb = b.build(&ctx, "p:latest").map_err(|e| e.to_string())?;
        if ra.image_id != rb.image_id {
            return Err("image ids diverged".into());
        }
        let _ = std::fs::remove_dir_all(&case_dir);
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn chunk_digest_incremental_matches_full_on_tar_edits() {
    // The exact incremental path the injector takes, on random tars and
    // random member replacements.
    check("tar splice + incremental chunk digest == full recompute", 25, |g| {
        let eng = NativeEngine::new();
        let n = g.len(1, 6);
        let mut b = layerjet::tar::TarBuilder::new();
        let mut names = Vec::new();
        for i in 0..n {
            let data = g.vec_u8(0, 6000);
            let name = format!("f{i}.py");
            b.append_file(&name, &data).unwrap();
            names.push(name);
        }
        let mut tar = b.finish();
        let cd = ChunkDigest::compute(&tar, &eng);

        let target = names[g.below(names.len() as u64) as usize].clone();
        let new_content = g.vec_u8(0, 6000);
        let ranges = layerjet::tar::replace_file(&mut tar, &target, &new_content)
            .map_err(|e| e.to_string())?;
        let (incremental, _) = cd.update(&tar, &ranges, &eng);
        let full = ChunkDigest::compute(&tar, &eng);
        if incremental != full {
            return Err(format!("mismatch for {target} len {}", new_content.len()));
        }
        if incremental.root != full.root || Digest::of(&tar) != Digest::of(&tar) {
            return Err("root mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn cache_invariant_cached_rebuild_is_identity() {
    let root = tmp("cache");
    let mut case = 0u64;
    check("immediate rebuild is fully cached and id-stable", 8, |g| {
        case += 1;
        let case_dir = root.join(format!("case{case}"));
        let ctx = case_dir.join("ctx");
        gen_project(g, &ctx);
        let d = daemon(&case_dir.join("d"));
        let r1 = d.build(&ctx, "p:latest").map_err(|e| e.to_string())?;
        let r2 = d.build(&ctx, "p:latest").map_err(|e| e.to_string())?;
        if r2.rebuilt_steps() != 0 {
            return Err(format!("{} steps rebuilt on identical context", r2.rebuilt_steps()));
        }
        if r1.image_id != r2.image_id {
            return Err("image id changed without a content change".into());
        }
        let _ = std::fs::remove_dir_all(&case_dir);
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&root);
}
