//! End-to-end tests of the chunk-addressed registry transport:
//! bit-identical pipelined pushes, O(changed-chunks) redeploy uploads,
//! and resume-after-interrupt on both push and pull.

use layerjet::prelude::*;
use layerjet::registry::{LayerManifest, LayerPushStatus, PullOptions, PushOptions};
use layerjet::util::prng::Prng;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lj-transport-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn daemon(root: &Path) -> Daemon {
    let mut daemon = Daemon::new(root).unwrap();
    daemon.cost = CostModel::instant();
    daemon
}

/// A project whose COPY layer is dominated by a big deterministic asset;
/// the mutable source file sorts last so edits stay chunk-local in the
/// layer tar.
fn write_project(dir: &Path, asset_len: usize) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("Dockerfile"),
        "FROM python:alpine\nCOPY . /srv/\nCMD [\"python\", \"zz_main.py\"]\n",
    )
    .unwrap();
    let mut asset = vec![0u8; asset_len];
    Prng::new(0x5eed).fill_bytes(&mut asset);
    std::fs::write(dir.join("aa_assets.bin"), &asset).unwrap();
    std::fs::write(dir.join("zz_main.py"), "print('v1')\n").unwrap();
}

/// Every file under `root`, relative path → bytes.
fn tree_snapshot(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(dir: &Path, prefix: &str, out: &mut BTreeMap<String, Vec<u8>>) {
        let mut entries: Vec<_> = std::fs::read_dir(dir).unwrap().map(|e| e.unwrap()).collect();
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let name = e.file_name().to_string_lossy().into_owned();
            let rel = if prefix.is_empty() { name } else { format!("{prefix}/{name}") };
            if e.file_type().unwrap().is_dir() {
                walk(&e.path(), &rel, out);
            } else {
                out.insert(rel, std::fs::read(e.path()).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, "", &mut out);
    out
}

/// Acceptance: a `jobs > 1` push must leave a bit-identical remote
/// directory tree (and identical accounting) to a serial push.
#[test]
fn pipelined_push_is_bit_identical_to_serial() {
    let root = tmp("identical");
    let proj = root.join("proj");
    write_project(&proj, 96 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();

    let serial_remote = RemoteRegistry::open(&root.join("remote-serial")).unwrap();
    let piped_remote = RemoteRegistry::open(&root.join("remote-piped")).unwrap();
    let s = dev
        .push_with("app:v1", &serial_remote, &PushOptions { jobs: 1, ..Default::default() })
        .unwrap();
    let p = dev
        .push_with("app:v1", &piped_remote, &PushOptions { jobs: 4, ..Default::default() })
        .unwrap();
    assert_eq!(s.bytes_uploaded, p.bytes_uploaded);
    assert_eq!(s.bytes_deduped, p.bytes_deduped);
    assert_eq!(s.chunks_uploaded, p.chunks_uploaded);
    assert_eq!(
        tree_snapshot(&root.join("remote-serial")),
        tree_snapshot(&root.join("remote-piped")),
        "pipelined push must be bit-identical to serial"
    );
    std::fs::remove_dir_all(&root).unwrap();
}

/// Acceptance: after a single-file clone-inject redeploy, the push
/// uploads O(changed chunks) — asserted as < 25% of the layer's bytes.
#[test]
fn one_line_redeploy_uploads_a_fraction_of_the_layer() {
    let root = tmp("dedup");
    let proj = root.join("proj");
    write_project(&proj, 256 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    dev.push("app:v1", &remote).unwrap();

    // One-line change + clone-inject: the paper's redeploy flow.
    let main = std::fs::read_to_string(proj.join("zz_main.py")).unwrap();
    std::fs::write(proj.join("zz_main.py"), format!("{main}print('v2')\n")).unwrap();
    dev.inject_with(
        &proj,
        "app:v1",
        "app:v2",
        &InjectOptions {
            clone_for_redeploy: true,
            cost: CostModel::instant(),
            ..Default::default()
        },
    )
    .unwrap();
    let report = dev
        .push_with("app:v2", &remote, &PushOptions { jobs: 4, ..Default::default() })
        .unwrap();

    // Only the cloned COPY layer travels, and of it only the chunks the
    // edit touched.
    let (_, img) = dev.image("app:v2").unwrap();
    let copy_tar = dev.layers.read_tar(&img.layer_ids[1]).unwrap();
    assert!(report.bytes_uploaded > 0, "the changed chunks do travel");
    assert!(
        report.bytes_uploaded < copy_tar.len() as u64 / 4,
        "one-line redeploy uploaded {} bytes of a {}-byte layer",
        report.bytes_uploaded,
        copy_tar.len()
    );
    assert!(
        report.bytes_deduped > copy_tar.len() as u64 / 2,
        "the unchanged bulk must negotiate away ({} deduped)",
        report.bytes_deduped
    );
    std::fs::remove_dir_all(&root).unwrap();
}

/// THE acceptance bar of the CDC wire format: inserting one line near
/// the top of a previously pushed multi-chunk COPY payload shifts every
/// downstream tar byte, yet the redeploy push uploads < 10% of the
/// layer — while the fixed-chunk v1 wire format re-uploads the shifted
/// bulk (the failure mode content-defined chunking exists to fix).
#[test]
fn shifted_insert_redeploy_uploads_under_10_percent() {
    let root = tmp("shifted");
    let proj = root.join("proj");
    write_project(&proj, 512 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();

    let cdc_remote = RemoteRegistry::open(&root.join("remote-cdc")).unwrap();
    let v1_remote = RemoteRegistry::open(&root.join("remote-v1")).unwrap();
    dev.push_with("app:v1", &cdc_remote, &PushOptions { jobs: 2, ..Default::default() })
        .unwrap();
    dev.push_with(
        "app:v1",
        &v1_remote,
        &PushOptions { jobs: 2, manifest_v1: true, ..Default::default() },
    )
    .unwrap();

    // Insert one line near the TOP of the dominant asset: every tar
    // byte after it shifts by a non-chunk-aligned amount.
    let asset_path = proj.join("aa_assets.bin");
    let asset = std::fs::read(&asset_path).unwrap();
    let line = b"# one inserted line\n";
    let mut shifted = Vec::with_capacity(asset.len() + line.len());
    shifted.extend_from_slice(&asset[..97]);
    shifted.extend_from_slice(line);
    shifted.extend_from_slice(&asset[97..]);
    std::fs::write(&asset_path, &shifted).unwrap();
    dev.inject_with(
        &proj,
        "app:v1",
        "app:v2",
        &InjectOptions {
            clone_for_redeploy: true,
            cost: CostModel::instant(),
            ..Default::default()
        },
    )
    .unwrap();

    let (_, img) = dev.image("app:v2").unwrap();
    let layer_bytes = dev.layers.read_tar(&img.layer_ids[1]).unwrap().len() as u64;

    let cdc = dev
        .push_with("app:v2", &cdc_remote, &PushOptions { jobs: 2, ..Default::default() })
        .unwrap();
    assert!(cdc.bytes_uploaded > 0, "the edit itself must travel");
    assert!(
        cdc.bytes_uploaded < layer_bytes / 10,
        "shifted insert uploaded {} of a {}-byte layer under CDC (must be < 10%)",
        cdc.bytes_uploaded,
        layer_bytes
    );
    assert!(
        cdc.bytes_deduped > layer_bytes * 8 / 10,
        "the shifted-but-unchanged bulk must negotiate away ({} deduped)",
        cdc.bytes_deduped
    );

    // Control: the fixed-chunk grid re-uploads everything downstream of
    // the insertion — the cost this PR removes.
    let fixed = dev
        .push_with(
            "app:v2",
            &v1_remote,
            &PushOptions { jobs: 2, manifest_v1: true, ..Default::default() },
        )
        .unwrap();
    assert!(
        fixed.bytes_uploaded > layer_bytes / 2,
        "fixed chunking should have re-uploaded the shifted bulk ({} of {})",
        fixed.bytes_uploaded,
        layer_bytes
    );

    // Both wire formats still deliver a byte-correct image.
    let prod = daemon(&root.join("prod"));
    prod.pull("app:v2", &cdc_remote).unwrap();
    assert!(prod.verify_image("app:v2").unwrap());
    let prod_v1 = daemon(&root.join("prod-v1"));
    prod_v1.pull("app:v2", &v1_remote).unwrap();
    assert!(prod_v1.verify_image("app:v2").unwrap());
    std::fs::remove_dir_all(&root).unwrap();
}

/// Compatibility: a remote populated with v1 fixed-chunk manifests (a
/// pre-CDC pusher) pulls under the new code, and the codecs coexist
/// per layer in one remote.
#[test]
fn v1_fixed_chunk_manifests_still_pull() {
    let root = tmp("v1-compat");
    let proj = root.join("proj");
    write_project(&proj, 96 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    dev.push_with("app:v1", &remote, &PushOptions { manifest_v1: true, ..Default::default() })
        .unwrap();
    let (_, img) = dev.image("app:v1").unwrap();
    for lid in &img.layer_ids {
        assert!(
            matches!(remote.layer_manifest(lid), Some(LayerManifest::V1(_))),
            "forced v1 push must write v1 manifests"
        );
    }

    let prod = daemon(&root.join("prod"));
    prod.pull("app:v1", &remote).unwrap();
    assert!(prod.verify_image("app:v1").unwrap());

    // A later v2-writer push into the SAME remote coexists: the new
    // layer gets a v2 manifest, the old layers stay v1, and both pull.
    std::fs::write(proj.join("zz_main.py"), "print('v2')\n").unwrap();
    dev.inject_with(
        &proj,
        "app:v1",
        "app:v2",
        &InjectOptions {
            clone_for_redeploy: true,
            cost: CostModel::instant(),
            ..Default::default()
        },
    )
    .unwrap();
    dev.push_with("app:v2", &remote, &PushOptions::default()).unwrap();
    let (_, img2) = dev.image("app:v2").unwrap();
    assert!(
        matches!(remote.layer_manifest(&img2.layer_ids[1]), Some(LayerManifest::V2(_))),
        "the cloned layer is written with the v2 codec"
    );
    let prod2 = daemon(&root.join("prod2"));
    prod2.pull("app:v2", &remote).unwrap();
    assert!(prod2.verify_image("app:v2").unwrap());
    std::fs::remove_dir_all(&root).unwrap();
}

/// An interrupted push (chunks streamed, commit never reached) resumes
/// without re-uploading the committed chunks.
#[test]
fn interrupted_push_resumes_without_reuploading_chunks() {
    let root = tmp("resume-push");
    let proj = root.join("proj");
    write_project(&proj, 128 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();
    let rdir = root.join("remote");
    let remote = RemoteRegistry::open(&rdir).unwrap();
    let first = dev
        .push_with("app:v1", &remote, &PushOptions { jobs: 2, ..Default::default() })
        .unwrap();
    assert!(first.bytes_uploaded > 0);

    // Simulate the interruption: everything the registry *serves* is
    // gone, but the content-addressed pool survived.
    std::fs::remove_dir_all(rdir.join("layers")).unwrap();
    std::fs::remove_dir_all(rdir.join("images")).unwrap();
    std::fs::write(rdir.join("tags.json"), "{}\n").unwrap();
    let remote = RemoteRegistry::open(&rdir).unwrap();

    let retry = dev
        .push_with("app:v1", &remote, &PushOptions { jobs: 2, ..Default::default() })
        .unwrap();
    assert!(
        retry.layers.iter().all(|(_, s)| *s != LayerPushStatus::AlreadyExists),
        "metadata was wiped, so every layer re-commits"
    );
    assert_eq!(retry.bytes_uploaded, 0, "committed chunks must not re-upload");
    assert!(retry.chunks_deduped > 0);

    // The resumed remote serves pulls.
    let prod = daemon(&root.join("prod"));
    prod.pull("app:v1", &remote).unwrap();
    assert!(prod.verify_image("app:v1").unwrap());
    std::fs::remove_dir_all(&root).unwrap();
}

/// Pull resume at both granularities: committed layers are skipped, and
/// chunks staged by an interrupted pull are replayed instead of fetched.
#[test]
fn pull_resumes_from_local_layers_and_staged_chunks() {
    let root = tmp("resume-pull");
    let proj = root.join("proj");
    write_project(&proj, 128 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    dev.push("app:v1", &remote).unwrap();

    let prod = daemon(&root.join("prod"));
    let first = prod.pull_with("app:v1", &remote, &PullOptions { jobs: 4, ..Default::default() }).unwrap();
    assert_eq!(first.layers_skipped, 0);
    assert!(first.bytes_fetched > 0);
    assert!(prod.verify_image("app:v1").unwrap());

    // Layer-level resume: drop one local layer; re-pull fetches just it.
    let (_, img) = prod.image("app:v1").unwrap();
    prod.layers.delete(&img.layer_ids[1]).unwrap();
    let second = prod.pull_with("app:v1", &remote, &PullOptions { jobs: 1, ..Default::default() }).unwrap();
    assert_eq!(second.layers_fetched, 1);
    assert_eq!(second.layers_skipped, img.layer_ids.len() - 1);
    assert!(prod.verify_image("app:v1").unwrap());

    // Repair: a crash can leave intact metadata over missing content —
    // in the chunk-backed layout, a pool chunk that never landed. Drop
    // a chunk only layer 1 references; the resume check verifies
    // content, so re-pull refetches exactly that layer.
    let manifest = prod.layers.cdc_manifest(&img.layer_ids[1]).unwrap();
    let mut elsewhere = std::collections::HashSet::new();
    for lid in img.layer_ids.iter().filter(|l| **l != img.layer_ids[1]) {
        if let Some(m) = prod.layers.cdc_manifest(lid) {
            elsewhere.extend(m.chunks.iter().map(|(d, _)| *d));
        }
    }
    let victim = manifest
        .chunks
        .iter()
        .map(|(d, _)| *d)
        .find(|d| !elsewhere.contains(d))
        .expect("layer 1 must own at least one unshared chunk");
    std::fs::remove_file(prod.layers.chunk_pool().root().join(victim.to_hex())).unwrap();
    let repaired = prod.pull_with("app:v1", &remote, &PullOptions { jobs: 1, ..Default::default() }).unwrap();
    assert_eq!(repaired.layers_fetched, 1, "corrupt local layer must be re-fetched");
    assert!(prod.verify_image("app:v1").unwrap());

    // Chunk-level resume: a fresh machine whose staging pool already
    // holds every chunk (what an interrupted pull leaves behind)
    // fetches nothing over the wire. Staging is keyed by image id.
    let cold_root = root.join("cold");
    let cold = daemon(&cold_root);
    let (image_id, _) = dev.image("app:v1").unwrap();
    let staging = cold_root.join("pull-staging").join(image_id.to_hex());
    std::fs::create_dir_all(&staging).unwrap();
    for entry in std::fs::read_dir(root.join("remote").join("chunks")).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), staging.join(entry.file_name())).unwrap();
    }
    let third = cold.pull_with("app:v1", &remote, &PullOptions { jobs: 2, ..Default::default() }).unwrap();
    assert_eq!(third.bytes_fetched, 0, "every chunk staged => nothing fetched");
    assert!(third.bytes_local > 0);
    assert!(cold.verify_image("app:v1").unwrap());
    assert!(!staging.exists(), "staging is cleared after a committed pull");

    // A poisoned staging entry (torn write from a crash) must not wedge
    // the pull: it is dropped, re-fetched from the wire, and the pull
    // still succeeds.
    let poisoned_root = root.join("poisoned");
    let poisoned = daemon(&poisoned_root);
    let bad_staging = poisoned_root.join("pull-staging").join(image_id.to_hex());
    std::fs::create_dir_all(&bad_staging).unwrap();
    let some_chunk = std::fs::read_dir(root.join("remote").join("chunks"))
        .unwrap()
        .next()
        .unwrap()
        .unwrap();
    std::fs::write(bad_staging.join(some_chunk.file_name()), b"torn write").unwrap();
    let repaired2 = poisoned.pull_with("app:v1", &remote, &PullOptions { jobs: 1, ..Default::default() }).unwrap();
    assert!(repaired2.bytes_fetched > 0);
    assert!(poisoned.verify_image("app:v1").unwrap());
    std::fs::remove_dir_all(&root).unwrap();
}

/// A multi-layer project for the negotiation-batching assertions.
fn write_multi_layer_project(dir: &Path, layers: usize) {
    std::fs::create_dir_all(dir).unwrap();
    let mut df = String::from("FROM python:alpine\n");
    for l in 0..layers {
        df.push_str(&format!("COPY part{l} /srv/part{l}/\n"));
    }
    df.push_str("CMD [\"python\", \"main.py\"]\n");
    std::fs::write(dir.join("Dockerfile"), df).unwrap();
    let mut rng = Prng::new(0xba7c4);
    for l in 0..layers {
        let part = dir.join(format!("part{l}"));
        std::fs::create_dir_all(&part).unwrap();
        let mut asset = vec![0u8; 64 * 1024];
        rng.fill_bytes(&mut asset);
        std::fs::write(part.join("aa_assets.bin"), &asset).unwrap();
        std::fs::write(part.join("zz_main.py"), "print('v1')\n").unwrap();
    }
}

/// Acceptance: chunk-existence negotiation is one batched round-trip per
/// uploaded layer, not one probe per chunk — the high-latency-remote
/// fix — while the per-chunk legacy mode stays available and transfers
/// the identical byte set.
#[test]
fn negotiation_is_one_round_trip_per_layer() {
    let root = tmp("negotiate");
    let proj = root.join("proj");
    write_multi_layer_project(&proj, 4);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "neg:v1").unwrap();
    let (_, img) = dev.image("neg:v1").unwrap();

    // Batched (default): one round-trip per uploaded layer (every layer
    // tar — even an empty layer's end-of-archive blocks — carries at
    // least one chunk on a cold remote).
    let batched_remote = RemoteRegistry::open(&root.join("remote-batched")).unwrap();
    let batched = dev
        .push_with("neg:v1", &batched_remote, &PushOptions::default())
        .unwrap();
    assert_eq!(
        batched.negotiation_round_trips,
        img.layer_ids.len(),
        "batched negotiation: one round-trip per uploaded layer"
    );

    // Per-chunk legacy mode: one probe per first-claimed chunk.
    let legacy_remote = RemoteRegistry::open(&root.join("remote-legacy")).unwrap();
    let legacy = dev
        .push_with(
            "neg:v1",
            &legacy_remote,
            &PushOptions { negotiate_per_chunk: true, ..Default::default() },
        )
        .unwrap();
    assert!(
        legacy.negotiation_round_trips >= legacy.chunks_uploaded,
        "per-chunk mode probes every distinct chunk ({} round-trips, {} chunks)",
        legacy.negotiation_round_trips,
        legacy.chunks_uploaded
    );
    assert!(
        legacy.negotiation_round_trips > batched.negotiation_round_trips,
        "batching must collapse the per-chunk probes"
    );

    // Same transferred set either way: bit-identical remote trees.
    assert_eq!(batched.bytes_uploaded, legacy.bytes_uploaded);
    assert_eq!(batched.chunks_uploaded, legacy.chunks_uploaded);
    assert_eq!(
        tree_snapshot(&root.join("remote-batched")),
        tree_snapshot(&root.join("remote-legacy")),
        "negotiation mode must not change the remote tree"
    );

    // Layer-level dedup short-circuits negotiation entirely.
    let again = dev
        .push_with("neg:v1", &batched_remote, &PushOptions::default())
        .unwrap();
    assert_eq!(again.negotiation_round_trips, 0, "AlreadyExists layers negotiate nothing");

    // A one-layer redeploy negotiates exactly once, at any jobs width.
    std::fs::write(proj.join("part2/zz_main.py"), "print('v2')\n").unwrap();
    dev.inject_with(
        &proj,
        "neg:v1",
        "neg:v2",
        &InjectOptions {
            clone_for_redeploy: true,
            cost: CostModel::instant(),
            ..Default::default()
        },
    )
    .unwrap();
    for jobs in [1, 4] {
        let rdir = root.join(format!("remote-redeploy-j{jobs}"));
        let remote = RemoteRegistry::open(&rdir).unwrap();
        dev.push_with("neg:v1", &remote, &PushOptions { jobs, ..Default::default() }).unwrap();
        let redeploy = dev
            .push_with("neg:v2", &remote, &PushOptions { jobs, ..Default::default() })
            .unwrap();
        assert_eq!(
            redeploy.negotiation_round_trips, 1,
            "jobs={jobs}: one changed layer, one negotiation round-trip"
        );
    }
    std::fs::remove_dir_all(&root).unwrap();
}

/// A v2 (CDC) pull killed at an injected chunk boundary resumes from the
/// staging pool: chunks fetched and verified before the kill are replayed
/// as local bytes instead of re-fetched over the wire.
#[test]
fn cdc_pull_killed_at_chunk_boundary_resumes_from_staging() {
    use layerjet::fault::{self, FaultMode, FaultPlan};

    let root = tmp("fault-pull");
    let proj = root.join("proj");
    write_project(&proj, 128 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    dev.push("app:v1", &remote).unwrap();
    let (image_id, _) = dev.image("app:v1").unwrap();

    // Kill 1: crash on the 5th wire-chunk read — mid-stream, at a chunk
    // boundary of whichever layer is assembling.
    let prod_root = root.join("prod");
    let prod = daemon(&prod_root);
    let guard = fault::install(
        FaultPlan::fail_at("registry.pool.get", 4, FaultMode::Crash).scoped(&root),
    );
    let killed = prod.pull_with("app:v1", &remote, &PullOptions { jobs: 1, ..Default::default() });
    drop(guard);
    let err = killed.expect_err("the injected crash must kill the pull");
    assert!(fault::error_is_crash(&err), "unexpected failure: {err:?}");

    // Kill 2: the next attempt dies on the first local layer commit —
    // after that layer's chunks were fetched, verified, and staged.
    let guard = fault::install(
        FaultPlan::fail_at("store.manifest.commit", 0, FaultMode::Crash).scoped(&prod_root),
    );
    let killed = prod.pull_with("app:v1", &remote, &PullOptions { jobs: 1, ..Default::default() });
    drop(guard);
    assert!(killed.is_err(), "the injected store crash must kill the pull");
    let staging = prod_root.join("pull-staging").join(image_id.to_hex());
    assert!(staging.exists(), "an interrupted pull must leave its staging pool behind");
    let staged = std::fs::read_dir(&staging)
        .unwrap()
        .filter(|e| e.as_ref().unwrap().file_name().to_string_lossy().len() == 64)
        .count();
    assert!(staged > 0, "verified chunks must be staged before the kill");

    // Resume: reopening the store sweeps the partial layer, and the
    // staged chunks replay as local bytes instead of wire fetches.
    let prod = daemon(&prod_root);
    let resumed = prod
        .pull_with("app:v1", &remote, &PullOptions { jobs: 1, ..Default::default() })
        .unwrap();
    assert!(resumed.chunks_local > 0, "staged chunks must be replayed: {resumed:?}");
    assert!(resumed.bytes_local > 0, "staged bytes count as local: {resumed:?}");
    assert!(prod.verify_image("app:v1").unwrap());
    assert!(!staging.exists(), "staging is cleared after the committed pull");
    std::fs::remove_dir_all(&root).unwrap();
}
