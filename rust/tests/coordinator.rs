//! Acceptance tests for fleet-wide step-level scheduling: fairness (no
//! convoying behind cold builds), single-flight dedup of identical
//! steps, per-daemon store-lock exclusion, and bit-identical output at
//! any pool width.

use layerjet::builder::CostModel;
use layerjet::coordinator::{BuildCoordinator, BuildRequest, BuildStrategy, SchedMode};
use layerjet::daemon::Daemon;
use std::path::{Path, PathBuf};
use std::time::Duration;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lj-coordtest-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn write_ctx(dir: &Path, dockerfile: &str, files: &[(&str, &str)]) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join("Dockerfile"), dockerfile).unwrap();
    for (p, c) in files {
        let path = dir.join(p);
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, c).unwrap();
    }
}

/// A cost model where only the per-step container overhead is simulated
/// — makes step counts visible in wall clock without byte-rate noise.
fn step_cost(ms: u64) -> CostModel {
    CostModel {
        step_overhead: Duration::from_millis(ms),
        cache_probe: Duration::ZERO,
        archive_ns_per_byte: 0,
        toolchain_ns_per_byte: 0,
    }
}

/// A cold project with `runs` independent RUN steps (plus FROM and CMD).
fn cold_project(dir: &Path, base: &str, runs: usize) {
    let mut df = format!("FROM {base}\n");
    for i in 0..runs {
        df.push_str(&format!("RUN pip install pkg{i:02}\n"));
    }
    df.push_str("CMD [\"python\"]\n");
    write_ctx(dir, &df, &[("main.py", "print('cold')\n")]);
}

fn request(id: u64, project: &Path, tag: &str) -> BuildRequest {
    BuildRequest {
        id,
        project: project.to_path_buf(),
        tag: tag.to_string(),
        strategy: BuildStrategy::DockerRebuild,
    }
}

/// Image id + every layer tar for a tag in one worker's daemon.
fn image_fingerprint(farm: &Path, worker: usize, tag: &str) -> (String, Vec<Vec<u8>>) {
    let daemon = Daemon::new(&farm.join(format!("worker-{worker}"))).unwrap();
    let (id, image) = daemon.image(tag).unwrap();
    assert!(daemon.verify_image(tag).unwrap(), "{tag} must verify");
    let tars = image
        .layer_ids
        .iter()
        .map(|l| daemon.layers.read_tar(l).unwrap())
        .collect();
    (id.to_hex(), tars)
}

/// Fairness: a 3-step request queued behind an 18-step cold build on the
/// same single-worker, single-job farm completes first — its steps
/// outrank the cold build's under shortest-remaining-work, instead of
/// waiting for the whole cold build as the per-request loop would.
#[test]
fn short_request_is_not_convoyed_by_cold_build() {
    let root = tmp("fair");
    let cold = root.join("cold");
    let short = root.join("short");
    cold_project(&cold, "ubuntu:latest", 16); // 18 steps total
    write_ctx(
        &short,
        "FROM python:alpine\nCOPY . /app/\nCMD [\"python\"]\n",
        &[("main.py", "print('quick')\n")],
    );
    let mut coordinator = BuildCoordinator::new(&root.join("farm"), 1);
    coordinator.cost = step_cost(10);
    coordinator.jobs = 1;
    // The cold build is first in the queue AND its driver starts first.
    let (outcomes, metrics) = coordinator
        .run(vec![request(1, &cold, "cold:latest"), request(2, &short, "short:latest")])
        .unwrap();
    assert!(outcomes.iter().all(|o| o.ok), "{outcomes:?}");
    assert_eq!(
        outcomes[0].id, 2,
        "the short request must complete before the cold build: {outcomes:?}"
    );
    let by_id = |id| outcomes.iter().find(|o| o.id == id).unwrap();
    assert!(
        by_id(2).service < by_id(1).service,
        "short service {:?} must undercut cold {:?}",
        by_id(2).service,
        by_id(1).service
    );
    assert_eq!(metrics.steps_scheduled, 18 + 3, "every step executed exactly once");
    std::fs::remove_dir_all(&root).unwrap();
}

/// Single-flight dedup: two queued requests for the same project execute
/// each shared step exactly once — one request leads every step job, the
/// other adopts the results — and both land the identical image.
#[test]
fn shared_prefix_steps_execute_exactly_once() {
    let root = tmp("dedup");
    let proj = root.join("proj");
    write_ctx(
        &proj,
        "FROM python:alpine\nCOPY . /app/\nRUN pip install alpha\nRUN pip install beta\n\
         RUN apt update\nCMD [\"python\"]\n",
        &[("main.py", "print('tenant')\n")],
    );
    let mut coordinator = BuildCoordinator::new(&root.join("farm"), 1);
    // Enough per-step cost that the second driver plans while the first
    // request's steps are still executing (the single-flight window).
    coordinator.cost = step_cost(30);
    coordinator.jobs = 4;
    let (outcomes, metrics) = coordinator
        .run(vec![request(1, &proj, "app:latest"), request(2, &proj, "app:latest")])
        .unwrap();
    assert!(outcomes.iter().all(|o| o.ok), "{outcomes:?}");
    // 6 steps in the Dockerfile: executed once for the whole queue...
    assert_eq!(
        metrics.steps_scheduled, 6,
        "shared steps must execute exactly once across requests: {outcomes:?}"
    );
    // ...and the other request adopted every one of them in flight.
    assert_eq!(
        metrics.steps_deduped, 6,
        "the twin request must dedup every step: {outcomes:?}"
    );
    // Both requests resolved to the same verified image.
    let (id, _) = image_fingerprint(&root.join("farm"), 0, "app:latest");
    assert!(!id.is_empty());
    std::fs::remove_dir_all(&root).unwrap();
}

/// Per-daemon lock exclusion: two different projects built concurrently
/// on ONE daemon (store phases interleaving under the per-daemon lock)
/// produce exactly the store a serial per-request run produces.
#[test]
fn concurrent_builds_on_one_daemon_match_serial() {
    let root = tmp("lock");
    let p1 = root.join("p1");
    let p2 = root.join("p2");
    cold_project(&p1, "python:alpine", 4);
    write_ctx(
        &p2,
        "FROM python:alpine\nCOPY . /srv/\nRUN pip install gamma\nCMD [\"python\"]\n",
        &[("serve.py", "print('p2')\n")],
    );
    let batch = |farm: &str, mode| {
        let mut c = BuildCoordinator::new(&root.join(farm), 1);
        c.cost = step_cost(5);
        c.jobs = 4;
        let (outcomes, _) = c
            .run_mode(
                vec![request(1, &p1, "one:latest"), request(2, &p2, "two:latest")],
                mode,
            )
            .unwrap();
        assert!(outcomes.iter().all(|o| o.ok), "{outcomes:?}");
    };
    batch("farm-concurrent", SchedMode::StepLevel);
    batch("farm-serial", SchedMode::PerRequest);
    for tag in ["one:latest", "two:latest"] {
        let a = image_fingerprint(&root.join("farm-concurrent"), 0, tag);
        let b = image_fingerprint(&root.join("farm-serial"), 0, tag);
        assert_eq!(a, b, "{tag}: concurrent store must equal serial store");
    }
    std::fs::remove_dir_all(&root).unwrap();
}

/// Acceptance: scheduler output is bit-identical to serial execution —
/// same image ids and layer tars for every request at any `--jobs`
/// width, including a deduped twin and a disjoint project in one batch.
#[test]
fn output_bit_identical_at_any_jobs_width() {
    let root = tmp("width");
    let shared = root.join("shared");
    let other = root.join("other");
    cold_project(&shared, "python:alpine", 5);
    write_ctx(
        &other,
        "FROM ubuntu:latest\nCOPY . /opt/\nRUN apt update && apt install curl -y\nCMD [\"sh\"]\n",
        &[("tool.sh", "echo hi\n")],
    );
    let batch = |farm: &str, jobs: usize| {
        let mut c = BuildCoordinator::new(&root.join(farm), 2);
        c.cost = CostModel::instant();
        c.jobs = jobs;
        let (outcomes, _) = c
            .run(vec![
                request(1, &shared, "shared:latest"),
                request(2, &other, "other:latest"),
                request(3, &shared, "shared:latest"),
            ])
            .unwrap();
        assert!(outcomes.iter().all(|o| o.ok), "jobs={jobs}: {outcomes:?}");
    };
    batch("farm-j1", 1);
    batch("farm-j8", 8);
    // Serial reference: a standalone daemon building each project.
    let reference = Daemon::new(&root.join("reference")).unwrap();
    reference.build(&shared, "shared:latest").unwrap();
    reference.build(&other, "other:latest").unwrap();
    for tag in ["shared:latest", "other:latest"] {
        let (ref_id, ref_image) = reference.image(tag).unwrap();
        let ref_tars: Vec<Vec<u8>> = ref_image
            .layer_ids
            .iter()
            .map(|l| reference.layers.read_tar(l).unwrap())
            .collect();
        for farm in ["farm-j1", "farm-j8"] {
            // Request 1 (and 3) land on worker 0, request 2 on worker 1.
            let worker = if tag == "shared:latest" { 0 } else { 1 };
            let (id, tars) = image_fingerprint(&root.join(farm), worker, tag);
            assert_eq!(id, ref_id.to_hex(), "{farm}/{tag}: image id drift");
            assert_eq!(tars, ref_tars, "{farm}/{tag}: layer tar drift");
        }
    }
    std::fs::remove_dir_all(&root).unwrap();
}

/// Mixed strategies under the shared pool: a cascade injection queued
/// with a cold build still lands the correct rebuilt image (its dirty
/// steps ride the same pool as the cold build's).
#[test]
fn cascade_injection_rides_the_shared_pool() {
    let root = tmp("cascade");
    let proj = root.join("proj");
    write_ctx(
        &proj,
        "FROM java:8\nCOPY src /code/src/\nRUN javac src/App.java\nCMD [\"java\", \"App\"]\n",
        &[("src/App.java", "class App { int v = 1; }")],
    );
    let cold = root.join("cold");
    cold_project(&cold, "ubuntu:latest", 6);
    let mut coordinator = BuildCoordinator::new(&root.join("farm"), 1);
    coordinator.cost = step_cost(5);
    coordinator.jobs = 2;
    // Seed build of the java project.
    let (outcomes, _) = coordinator
        .run(vec![request(1, &proj, "app:latest")])
        .unwrap();
    assert!(outcomes[0].ok, "{outcomes:?}");
    // Revise the source; queue the injection behind a cold build.
    std::fs::write(proj.join("src/App.java"), "class App { int v = 2; }").unwrap();
    let (outcomes, metrics) = coordinator
        .run(vec![
            request(2, &cold, "cold:latest"),
            BuildRequest {
                id: 3,
                project: proj.clone(),
                tag: "app:latest".into(),
                strategy: BuildStrategy::InjectCascade,
            },
        ])
        .unwrap();
    assert!(outcomes.iter().all(|o| o.ok), "{outcomes:?}");
    // The compile step re-executed on the pool (cold steps + >=1 dirty).
    assert!(metrics.steps_scheduled > 8, "{metrics:?}");
    // The recompiled class is in the image a fresh daemon would build.
    let scratch = Daemon::new(&root.join("scratch")).unwrap();
    scratch.build(&proj, "app:latest").unwrap();
    let a = image_fingerprint(&root.join("farm"), 0, "app:latest");
    let (sid, simage) = scratch.image("app:latest").unwrap();
    let stars: Vec<Vec<u8>> = simage
        .layer_ids
        .iter()
        .map(|l| scratch.layers.read_tar(l).unwrap())
        .collect();
    assert_eq!(a.0, sid.to_hex(), "cascade image == scratch image");
    assert_eq!(a.1, stars);
    std::fs::remove_dir_all(&root).unwrap();
}
