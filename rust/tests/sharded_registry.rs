//! End-to-end tests of the sharded chunk pool and the persistent
//! read-through pull cache: resharding migrates only a minority of
//! chunks and never changes what a pull observes, a warm edge cache
//! keeps origin traffic under 10% of pulled bytes, and maintenance
//! (round-robin scrub, sharded gc) still repairs and collects across
//! every backend.
//!
//! The `replication_*` tests (the CI `replication` filter) cover the
//! R=2 placement story: a push with a dead replica backend still
//! commits and records under-replication markers, pulls fail over to
//! surviving copies and report it, `repair` converges the pool back to
//! full replication, and a ring shrink drains the departing backend
//! before the membership commit.

use layerjet::fault::{self, FaultMode, FaultPlan};
use layerjet::prelude::*;
use layerjet::registry::{LeaseConfig, PullCache, PullOptions};
use layerjet::util::prng::Prng;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lj-sharded-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn daemon(root: &Path) -> Daemon {
    let mut daemon = Daemon::new(root).unwrap();
    daemon.cost = CostModel::instant();
    daemon
}

/// A project whose COPY layer carries enough deterministic bytes to
/// spread across every shard of a small ring.
fn write_project(dir: &Path, asset_len: usize) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("Dockerfile"),
        "FROM python:alpine\nCOPY . /srv/\nCMD [\"python\", \"zz_main.py\"]\n",
    )
    .unwrap();
    let mut asset = vec![0u8; asset_len];
    Prng::new(0x5aa_5eed).fill_bytes(&mut asset);
    std::fs::write(dir.join("aa_assets.bin"), &asset).unwrap();
    std::fs::write(dir.join("zz_main.py"), "print('v1')\n").unwrap();
}

/// Every file under `root`, relative path → bytes.
fn tree_snapshot(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(dir: &Path, prefix: &str, out: &mut BTreeMap<String, Vec<u8>>) {
        let mut entries: Vec<_> = std::fs::read_dir(dir).unwrap().map(|e| e.unwrap()).collect();
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let name = e.file_name().to_string_lossy().into_owned();
            let rel = if prefix.is_empty() { name } else { format!("{prefix}/{name}") };
            if e.file_type().unwrap().is_dir() {
                walk(&e.path(), &rel, out);
            } else {
                out.insert(rel, std::fs::read(e.path()).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, "", &mut out);
    out
}

/// Acceptance (headline): with a warm pull cache, a fresh store's pull
/// moves < 10% of its bytes from the origin; cold is ~100%.
#[test]
fn warm_pull_cache_cuts_origin_bytes_below_ten_percent() {
    let root = tmp("cache");
    let proj = root.join("proj");
    write_project(&proj, 256 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    dev.push("app:v1", &remote).unwrap();

    let cache = PullCache::open_default(&root.join("edge-cache")).unwrap();

    // Cold: every transferred byte comes from the origin, and each wire
    // fetch is written through to the cache.
    let prod1 = daemon(&root.join("prod1"));
    let cold = prod1
        .pull_with(
            "app:v1",
            &remote,
            &PullOptions { jobs: 2, pull_cache: Some(cache.clone()), ..Default::default() },
        )
        .unwrap();
    assert!(prod1.verify_image("app:v1").unwrap());
    assert!(cold.bytes_from_origin > 0, "cold pull must hit the origin: {cold:?}");
    assert_eq!(cold.bytes_from_cache, 0, "nothing can be cached yet: {cold:?}");
    assert_eq!(
        cold.bytes_from_origin, cold.bytes_fetched,
        "cold: every fetched byte is an origin byte"
    );

    // Warm: a different machine (fresh store, empty staging) pulls the
    // same image through the shared edge cache.
    let prod2 = daemon(&root.join("prod2"));
    let warm = prod2
        .pull_with(
            "app:v1",
            &remote,
            &PullOptions { jobs: 2, pull_cache: Some(cache.clone()), ..Default::default() },
        )
        .unwrap();
    assert!(prod2.verify_image("app:v1").unwrap());
    let transferred = warm.bytes_from_origin + warm.bytes_from_cache;
    assert!(transferred > 0, "the fresh store must transfer something: {warm:?}");
    assert!(
        warm.bytes_from_origin * 10 < transferred,
        "warm cache must keep origin bytes under 10% of {transferred}: {warm:?}"
    );
    let stats = cache.stats();
    assert!(stats.hits > 0, "warm pull must be served by cache hits: {stats:?}");
    assert!(stats.bytes_served >= warm.bytes_from_cache);
    std::fs::remove_dir_all(&root).unwrap();
}

/// Acceptance: growing the ring 2 → 3 migrates fewer than half the
/// chunks (consistent hashing moves ~1/3 of the keyspace), occupancy
/// spreads over every backend, and a pull after the reshard leaves a
/// store bit-identical to one pulled before it.
#[test]
fn reshard_two_to_three_migrates_minority_and_pulls_bit_identical() {
    let root = tmp("grow");
    let proj = root.join("proj");
    write_project(&proj, 256 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    dev.push("app:v1", &remote).unwrap();
    remote.shard_to(2).unwrap();

    let before_store = daemon(&root.join("before"));
    before_store.pull("app:v1", &remote).unwrap();
    assert!(before_store.verify_image("app:v1").unwrap());
    let want = tree_snapshot(&root.join("before"));

    let report = remote.shard_to(3).unwrap();
    assert_eq!(report.shards, 3);
    assert!(report.chunks_migrated > 0, "growing the ring must move something: {report:?}");
    assert!(
        report.chunks_migrated * 2 < report.chunks_scanned,
        "2→3 must migrate a strict minority of chunks: {report:?}"
    );

    let (stats, balance) = remote.shard_stats().unwrap();
    assert_eq!(stats.len(), 3);
    assert!(stats.iter().all(|s| s.chunks > 0), "every backend should hold chunks: {stats:?}");
    assert!(balance >= 1.0, "balance factor is max/mean: {balance}");

    let after_store = daemon(&root.join("after"));
    after_store.pull("app:v1", &remote).unwrap();
    assert!(after_store.verify_image("app:v1").unwrap());
    assert_eq!(
        tree_snapshot(&root.join("after")),
        want,
        "a pull through the resharded pool must be bit-identical"
    );
    std::fs::remove_dir_all(&root).unwrap();
}

/// A reshard killed mid-migration leaves a pool that still serves
/// bit-identical pulls (the committed ring keeps every chunk reachable),
/// and re-running the reshard converges on the target layout.
#[test]
fn interrupted_reshard_keeps_pulls_bit_identical_and_resumes() {
    let root = tmp("resume");
    let proj = root.join("proj");
    write_project(&proj, 192 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();
    // Zero ttl: the exclusive lease stranded by the injected crash is
    // reclaimed at the next acquisition instead of stalling the test.
    let remote = RemoteRegistry::open_with(
        &root.join("remote"),
        LeaseConfig { ttl: std::time::Duration::ZERO, ..Default::default() },
    )
    .unwrap();
    dev.push("app:v1", &remote).unwrap();
    remote.shard_to(2).unwrap();

    let before_store = daemon(&root.join("before"));
    before_store.pull("app:v1", &remote).unwrap();
    let want = tree_snapshot(&root.join("before"));

    // Kill the migration at its third arrival at the migrate site.
    let guard =
        fault::install(FaultPlan::fail_at("registry.shard.migrate", 2, FaultMode::Crash).scoped(&root));
    let killed = remote.shard_to(3);
    drop(guard);
    assert!(killed.is_err(), "the injected crash must surface");

    // Mid-migration: the committed descriptor still routes every chunk
    // to a backend that holds it, so a pull sees nothing amiss.
    let during_store = daemon(&root.join("during"));
    during_store.pull("app:v1", &remote).unwrap();
    assert!(during_store.verify_image("app:v1").unwrap());
    assert_eq!(
        tree_snapshot(&root.join("during")),
        want,
        "a pull during a crashed reshard must be bit-identical"
    );

    // Resume: re-running the reshard converges on three clean backends
    // (no duplicate copies, no orphaned temp files).
    let resumed = remote.shard_to(3).unwrap();
    assert_eq!(resumed.shards, 3);
    let (stats, _) = remote.shard_stats().unwrap();
    assert_eq!(stats.len(), 3);
    let total: usize = stats.iter().map(|s| s.chunks).sum();
    assert_eq!(
        total,
        resumed.chunks_scanned - resumed.chunks_cleaned,
        "no chunk may survive in two backends after convergence"
    );
    for (rel, _) in tree_snapshot(&root.join("remote")) {
        assert!(!rel.contains(".tmp-"), "orphaned temp file {rel}");
    }

    let after_store = daemon(&root.join("after"));
    after_store.pull("app:v1", &remote).unwrap();
    assert!(after_store.verify_image("app:v1").unwrap());
    assert_eq!(
        tree_snapshot(&root.join("after")),
        want,
        "a pull after the resumed reshard must be bit-identical"
    );
    std::fs::remove_dir_all(&root).unwrap();
}

/// Maintenance still works across shards: scrub's round-robin passes
/// find rot on any backend and demote the affected layer, the next push
/// repairs it, and gc sweeps an untagged image's chunks off every shard.
#[test]
fn scrub_and_gc_cover_every_shard_backend() {
    let root = tmp("maint");
    let proj = root.join("proj");
    write_project(&proj, 192 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    dev.push("app:v1", &remote).unwrap();
    remote.shard_to(3).unwrap();

    // Rot one chunk on a non-root backend (shard-1 or shard-2).
    let shard_chunks = std::fs::read_dir(root.join("remote"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.file_name().unwrap().to_string_lossy().starts_with("shard-"))
        .map(|p| p.join("chunks"))
        .find(|p| std::fs::read_dir(p).map(|mut d| d.next().is_some()).unwrap_or(false))
        .expect("a non-root backend must hold chunks");
    let victim = std::fs::read_dir(&shard_chunks).unwrap().next().unwrap().unwrap().path();
    std::fs::write(&victim, b"bit rot").unwrap();

    let scrubbed = remote.scrub().unwrap();
    assert_eq!(scrubbed.chunks_dropped, 1, "the rotted chunk must be dropped: {scrubbed:?}");
    assert!(scrubbed.layers_demoted >= 1, "its layer must be demoted: {scrubbed:?}");

    // The next push of the same image repairs the demoted layer.
    let repaired = dev.push("app:v1", &remote).unwrap();
    assert!(repaired.bytes_uploaded > 0, "repair must re-upload the missing chunk");
    let prod = daemon(&root.join("prod"));
    prod.pull("app:v1", &remote).unwrap();
    assert!(prod.verify_image("app:v1").unwrap());

    // gc after untag sweeps every backend empty.
    remote.untag(&layerjet::oci::ImageRef::parse("app:v1")).unwrap();
    let gc = remote.gc().unwrap();
    assert!(gc.chunks_dropped > 0, "untagged image's chunks must be collected: {gc:?}");
    let (stats, _) = remote.shard_stats().unwrap();
    assert!(
        stats.iter().all(|s| s.chunks == 0),
        "gc must sweep every shard backend: {stats:?}"
    );
    std::fs::remove_dir_all(&root).unwrap();
}

/// Acceptance: at R=2 a push with one replica backend down still
/// commits (and records what it could not place), every tag keeps
/// pulling bit-identically while either backend is dead — with the
/// report counting the failover reads — and an anti-entropy `repair`
/// drains the markers back to a fully replicated pool.
#[test]
fn replication_degraded_push_failover_pulls_and_repair_convergence() {
    let root = tmp("replication");
    let proj = root.join("proj");
    write_project(&proj, 192 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    dev.push("app:v1", &remote).unwrap();

    // Two backends, two copies of everything.
    let sharded = remote.shard_to_with(2, 2).unwrap();
    assert_eq!(sharded.shards, 2);
    let occ = remote.occupancy().unwrap();
    assert!(occ.unique_chunks > 0);
    assert_eq!(
        occ.replica_chunks,
        occ.unique_chunks * 2,
        "R=2 means two copies of every chunk: {occ:?}"
    );
    assert_eq!(occ.under_replicated, 0, "{occ:?}");

    // v2 lands while backend shard-1 is down for writes: the push
    // commits on the surviving copies and marks the rest.
    std::fs::write(proj.join("zz_main.py"), "print('v2')\n").unwrap();
    dev.build(&proj, "app:v2").unwrap();
    let shard1 = root.join("remote").join("shard-1");
    let writes_down = fault::install(
        FaultPlan::fail_at("registry.backend.write", 0, FaultMode::Unavailable(u32::MAX))
            .scoped(&shard1),
    );
    dev.push("app:v2", &remote).unwrap();
    drop(writes_down);
    let markers = remote.under_replicated().unwrap();
    assert!(!markers.is_empty(), "a degraded push must record under-replication");

    // Both tags still pull with shard-1 fully dead: reads fail over to
    // the surviving copy and the report says so.
    let backend_down = fault::install(
        FaultPlan::fail_at("registry.backend.read", 0, FaultMode::Unavailable(u32::MAX))
            .and("registry.backend.write", 0, FaultMode::Unavailable(u32::MAX))
            .scoped(&shard1),
    );
    // (The cold v1 pull moves every chunk, so its report is the robust
    // place to observe failovers; v2 then only fetches its novel tail.)
    let degraded = daemon(&root.join("degraded"));
    let report = degraded
        .pull_with("app:v1", &remote, &PullOptions { jobs: 2, ..Default::default() })
        .unwrap();
    degraded.pull("app:v2", &remote).unwrap();
    drop(backend_down);
    assert!(degraded.verify_image("app:v1").unwrap());
    assert!(degraded.verify_image("app:v2").unwrap());
    assert!(
        report.failover_reads > 0,
        "a dead home backend must surface as failover reads: {report:?}"
    );

    // Repair with the backend restored: the markers drain and the pool
    // converges back to two copies of everything.
    let repair = remote.repair().unwrap();
    assert!(repair.chunks_repaired > 0, "repair must re-replicate the degraded push: {repair:?}");
    assert!(repair.is_converged(), "{repair:?}");
    assert!(remote.under_replicated().unwrap().is_empty());
    let occ = remote.occupancy().unwrap();
    assert_eq!(occ.replica_chunks, occ.unique_chunks * 2, "post-repair: {occ:?}");
    assert_eq!(occ.under_replicated, 0, "{occ:?}");
    let again = remote.repair().unwrap();
    assert_eq!(again.chunks_repaired, 0, "repair must be idempotent: {again:?}");

    // Baselines pulled through the healthy pool match the degraded
    // store bit for bit...
    let clean = daemon(&root.join("clean"));
    clean.pull("app:v1", &remote).unwrap();
    clean.pull("app:v2", &remote).unwrap();
    let want = tree_snapshot(&root.join("clean"));
    assert_eq!(
        tree_snapshot(&root.join("degraded")),
        want,
        "pulls through a half-dead pool must be bit-identical"
    );

    // ...and so do pulls with the *other* backend dead.
    let root_backend = root.join("remote").join("chunks");
    let other_down = fault::install(
        FaultPlan::fail_at("registry.backend.read", 0, FaultMode::Unavailable(u32::MAX))
            .scoped(&root_backend),
    );
    let survivor = daemon(&root.join("survivor"));
    let report = survivor
        .pull_with("app:v1", &remote, &PullOptions { jobs: 2, ..Default::default() })
        .unwrap();
    survivor.pull("app:v2", &remote).unwrap();
    drop(other_down);
    assert!(
        report.failover_reads > 0,
        "losing shard 0 must surface as failover reads: {report:?}"
    );
    assert!(survivor.verify_image("app:v1").unwrap());
    assert!(survivor.verify_image("app:v2").unwrap());
    assert_eq!(
        tree_snapshot(&root.join("survivor")),
        want,
        "pulls with the other backend dead must be bit-identical"
    );
    std::fs::remove_dir_all(&root).unwrap();
}

/// Shrinking a replicated ring drains the departing backend before the
/// membership commit: after `shard_to_with(n-1, 2)` the stranded tree
/// is gone, the pool is still fully replicated, and a shrink killed
/// mid-drain keeps serving bit-identical pulls until a re-run converges.
#[test]
fn replication_shrink_drains_departing_backend_and_resumes() {
    let root = tmp("replshrink");
    let proj = root.join("proj");
    write_project(&proj, 192 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();
    // Zero ttl: the exclusive lease stranded by the injected crash is
    // reclaimed at the next acquisition instead of stalling the test.
    let remote = RemoteRegistry::open_with(
        &root.join("remote"),
        LeaseConfig { ttl: std::time::Duration::ZERO, ..Default::default() },
    )
    .unwrap();
    dev.push("app:v1", &remote).unwrap();
    remote.shard_to_with(3, 2).unwrap();

    let before_store = daemon(&root.join("before"));
    before_store.pull("app:v1", &remote).unwrap();
    let want = tree_snapshot(&root.join("before"));

    // Kill the shrink partway through its drain copies.
    let guard = fault::install(
        FaultPlan::fail_at("registry.shard.migrate", 2, FaultMode::Crash).scoped(&root),
    );
    let killed = remote.shard_to_with(2, 2);
    drop(guard);
    assert!(killed.is_err(), "the injected crash must surface");

    // Mid-shrink the committed 3-shard ring still routes every chunk to
    // a live copy.
    let during_store = daemon(&root.join("during"));
    during_store.pull("app:v1", &remote).unwrap();
    assert!(during_store.verify_image("app:v1").unwrap());
    assert_eq!(
        tree_snapshot(&root.join("during")),
        want,
        "a pull during a crashed shrink must be bit-identical"
    );

    // Re-running converges: the departing backend was drained to its
    // surviving replica homes and its tree removed.
    let resumed = remote.shard_to_with(2, 2).unwrap();
    assert_eq!(resumed.shards, 2);
    assert!(
        !root.join("remote").join("shard-2").exists(),
        "the departing backend must be drained and removed"
    );
    let occ = remote.occupancy().unwrap();
    assert_eq!(
        occ.replica_chunks,
        occ.unique_chunks * 2,
        "the shrunk pool must stay fully replicated: {occ:?}"
    );
    assert_eq!(occ.under_replicated, 0, "{occ:?}");

    let after_store = daemon(&root.join("after"));
    after_store.pull("app:v1", &remote).unwrap();
    assert!(after_store.verify_image("app:v1").unwrap());
    assert_eq!(
        tree_snapshot(&root.join("after")),
        want,
        "a pull after the resumed shrink must be bit-identical"
    );
    std::fs::remove_dir_all(&root).unwrap();
}
