//! Acceptance tests for the layer-free chunk-backed `LayerStore`:
//! an edit history costs O(unique content) on disk, reconstruction is
//! bit-identical to the legacy tar-per-layer layout at any `--jobs`,
//! and a push from a chunk-backed store is a pure manifest exchange
//! (`PushReport::chunks_rehashed == 0`).

use layerjet::hash::{ChunkDigest, NativeEngine};
use layerjet::oci::LayerMeta;
use layerjet::prelude::*;
use layerjet::registry::{PullOptions, PushOptions};
use layerjet::store::{LayerStore, LAYER_VERSION};
use layerjet::tar::TarBuilder;
use layerjet::util::prng::Prng;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lj-dedup-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn daemon(root: &Path) -> Daemon {
    let mut daemon = Daemon::new(root).unwrap();
    daemon.cost = CostModel::instant();
    daemon
}

/// A project whose COPY layer is dominated by a big deterministic asset;
/// the mutable source file sorts last so edits stay chunk-local in the
/// layer tar.
fn write_project(dir: &Path, asset_len: usize) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("Dockerfile"),
        "FROM python:alpine\nCOPY . /srv/\nCMD [\"python\", \"zz_main.py\"]\n",
    )
    .unwrap();
    let mut asset = vec![0u8; asset_len];
    Prng::new(0x5eed).fill_bytes(&mut asset);
    std::fs::write(dir.join("aa_assets.bin"), &asset).unwrap();
    std::fs::write(dir.join("zz_main.py"), "print('v1')\n").unwrap();
}

/// One revision of a project layer: a constant 1 MiB asset plus a tiny
/// source file that changes every revision. The asset sorts first so
/// the per-revision delta sits at the tar tail.
fn revision_layer(asset: &[u8], rev: usize) -> (LayerMeta, Vec<u8>) {
    let mut b = TarBuilder::new();
    b.append_file("aa_assets.bin", asset).unwrap();
    b.append_file("zz_main.py", format!("print('rev {rev}')\n").as_bytes()).unwrap();
    let tar = b.finish();
    let created_by = format!("COPY . /srv/ # rev {rev}");
    let id = LayerId::derive("dedup", None, &created_by);
    let meta = LayerMeta {
        id,
        parent: None,
        parent_checksum: None,
        checksum: Digest::of(&tar),
        chunk_root: ChunkDigest::compute(&tar, &NativeEngine::new()).root,
        created_by,
        source_checksum: Digest([0u8; 32]),
        is_empty_layer: false,
        size: tar.len() as u64,
        version: LAYER_VERSION.into(),
    };
    (meta, tar)
}

/// Total bytes of every regular file under `root`.
fn disk_usage(root: &Path) -> u64 {
    fn walk(dir: &Path, total: &mut u64) {
        for e in std::fs::read_dir(dir).unwrap() {
            let e = e.unwrap();
            if e.file_type().unwrap().is_dir() {
                walk(&e.path(), total);
            } else {
                *total += e.metadata().unwrap().len();
            }
        }
    }
    let mut total = 0;
    walk(root, &mut total);
    total
}

/// Every file under `root`, relative path → bytes.
fn tree_snapshot(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(dir: &Path, prefix: &str, out: &mut BTreeMap<String, Vec<u8>>) {
        let mut entries: Vec<_> = std::fs::read_dir(dir).unwrap().map(|e| e.unwrap()).collect();
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let name = e.file_name().to_string_lossy().into_owned();
            let rel = if prefix.is_empty() { name } else { format!("{prefix}/{name}") };
            if e.file_type().unwrap().is_dir() {
                walk(&e.path(), &rel, out);
            } else {
                out.insert(rel, std::fs::read(e.path()).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, "", &mut out);
    out
}

/// The tentpole claim: a 50-revision one-file-edit history costs
/// O(unique content), not O(revisions). The ISSUE acceptance bound is
/// "< 2x one revision's bytes" on stored content; each revision shares
/// the 1 MiB asset's chunks and contributes only the tar tail it
/// actually changed.
#[test]
fn fifty_revision_history_costs_unique_content() {
    let root = tmp("history");
    let mut asset = vec![0u8; 1 << 20];
    Prng::new(0xd15c).fill_bytes(&mut asset);
    let eng = NativeEngine::new();

    // Reference: a store holding exactly one revision.
    let single = LayerStore::open(&root.join("single")).unwrap();
    let (m0, t0) = revision_layer(&asset, 0);
    single.put_layer(&m0, &t0, &eng).unwrap();
    let single_pool = single.stats().unwrap().pool_bytes;

    // The history: 50 revisions of the same project, each a distinct
    // layer (distinct `created_by` → distinct `LayerId`).
    let hist = LayerStore::open(&root.join("hist")).unwrap();
    let mut logical = 0u64;
    for rev in 0..50 {
        let (meta, tar) = revision_layer(&asset, rev);
        hist.put_layer(&meta, &tar, &eng).unwrap();
        logical += tar.len() as u64;
    }

    let st = hist.stats().unwrap();
    assert_eq!((st.layers, st.chunk_backed, st.legacy), (50, 50, 0));
    assert_eq!(st.logical_bytes, logical);
    assert!(
        st.pool_bytes < 2 * single_pool,
        "50-revision history must cost < 2x one revision's content: pool {} vs single {}",
        st.pool_bytes,
        single_pool
    );

    // Whole-store footprint (content + per-revision manifests and
    // sidecars) stays a small fraction of the 50 tar bodies a
    // tar-per-layer layout would hold.
    let on_disk = disk_usage(&root.join("hist"));
    assert!(
        on_disk < logical / 5,
        "store footprint {} must be well under the {} logical bytes",
        on_disk,
        logical
    );

    // Sharing chunks must not cost fidelity: spot-check reconstruction
    // across the history.
    for rev in [0usize, 17, 49] {
        let (meta, tar) = revision_layer(&asset, rev);
        assert_eq!(hist.read_tar(&meta.id).unwrap(), tar, "rev {rev} must reconstruct exactly");
        assert!(hist.verify(&meta.id).unwrap());
    }
}

/// Build → implicit inject → push → pull: every push from a
/// chunk-backed store is a manifest exchange (zero chunks re-hashed),
/// and pulls at any `--jobs` width reconstruct bit-identical layers.
#[test]
fn push_pull_round_trip_is_bit_identical_with_zero_rechunking() {
    let root = tmp("roundtrip");
    let proj = root.join("proj");
    write_project(&proj, 192 * 1024);
    let dev = daemon(&root.join("dev"));
    dev.build(&proj, "app:v1").unwrap();

    // The paper's redeploy: edit one source file, inject in place.
    std::fs::write(proj.join("zz_main.py"), "print('v2')\n").unwrap();
    dev.inject(&proj, "app:v1", "app:v1").unwrap();

    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    let push = dev
        .push_with("app:v1", &remote, &PushOptions { jobs: 2, ..Default::default() })
        .unwrap();
    assert_eq!(
        push.chunks_rehashed, 0,
        "push from a chunk-backed store must reuse stored manifests, not re-chunk"
    );

    let (_, img) = dev.image("app:v1").unwrap();
    for jobs in [1usize, 4] {
        let prod = daemon(&root.join(format!("prod-{jobs}")));
        prod.pull_with("app:v1", &remote, &PullOptions { jobs, ..Default::default() }).unwrap();
        assert!(prod.verify_image("app:v1").unwrap());
        for lid in &img.layer_ids {
            assert_eq!(
                prod.layers.read_tar(lid).unwrap(),
                dev.layers.read_tar(lid).unwrap(),
                "layer {} must be bit-identical after a jobs={jobs} pull",
                lid.short()
            );
        }
    }

    // Re-pushing the same image is pure dedup — still nothing re-chunked.
    let again = dev.push("app:v1", &remote).unwrap();
    assert_eq!(again.chunks_rehashed, 0);
    assert_eq!(again.chunks_uploaded, 0);
}

/// Back-compat: a store demoted by hand to the pre-pool layout (tar
/// bodies in, manifests out) still reads, verifies, and pushes — and
/// `migrate` converts it eagerly with bit-identical reads and restores
/// the zero-re-chunk push path.
#[test]
fn legacy_store_round_trips_and_migrates_bit_identically() {
    let root = tmp("legacy");
    let proj = root.join("proj");
    write_project(&proj, 96 * 1024);
    {
        let dev = daemon(&root.join("dev"));
        dev.build(&proj, "app:v1").unwrap();
    }

    // Demote: materialize every layer as a tar body, drop the
    // manifests, empty the pool — exactly what a store written by a
    // pre-pool daemon looks like.
    let dev = daemon(&root.join("dev"));
    let mut tars: Vec<(LayerId, Vec<u8>)> = Vec::new();
    for lid in dev.layers.list().unwrap() {
        let tar = dev.layers.read_tar(&lid).unwrap();
        std::fs::write(dev.layers.tar_path(&lid), &tar).unwrap();
        let manifest = dev.layers.layer_dir(&lid).join("layer.manifest");
        if manifest.exists() {
            std::fs::remove_file(&manifest).unwrap();
        }
        tars.push((lid, tar));
    }
    for digest in dev.layers.chunk_pool().list().unwrap() {
        dev.layers.chunk_pool().remove(&digest).unwrap();
    }
    drop(dev);

    let dev = daemon(&root.join("dev"));
    let st = dev.layers.stats().unwrap();
    assert_eq!(st.chunk_backed, 0);
    assert_eq!(st.legacy, tars.len());
    for (lid, tar) in &tars {
        assert_eq!(dev.layers.read_tar(lid).unwrap(), *tar, "legacy read of {}", lid.short());
    }
    assert!(dev.verify_image("app:v1").unwrap());

    // A legacy push works but pays the re-chunk the manifest removes.
    let legacy_remote = RemoteRegistry::open(&root.join("remote-legacy")).unwrap();
    let legacy_push = dev.push("app:v1", &legacy_remote).unwrap();
    assert!(legacy_push.chunks_rehashed > 0, "legacy layout must re-chunk on push");

    // Eager migration: every layer converted, reads bit-identical,
    // pushes back to manifest exchange.
    let report = dev.migrate_store().unwrap();
    assert_eq!(report.layers_converted, tars.len());
    assert_eq!(report.layers_already_chunked, 0);
    for (lid, tar) in &tars {
        assert_eq!(dev.layers.read_tar(lid).unwrap(), *tar, "post-migrate read of {}", lid.short());
    }
    assert!(dev.verify_image("app:v1").unwrap());

    let migrated_remote = RemoteRegistry::open(&root.join("remote-migrated")).unwrap();
    let migrated_push = dev.push("app:v1", &migrated_remote).unwrap();
    assert_eq!(migrated_push.chunks_rehashed, 0);

    // Layout must never leak onto the wire: both remotes hold
    // bit-identical trees.
    assert_eq!(tree_snapshot(&root.join("remote-legacy")), tree_snapshot(&root.join("remote-migrated")));
}
