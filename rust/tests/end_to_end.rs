//! End-to-end: the paper's four scenarios through the experiment driver,
//! with **deterministic** work-based assertions (bytes archived, layers
//! rebuilt, chunks rehashed) rather than flaky wall-clock ones — the
//! timing claims live in the release-mode benches.

use layerjet::bench::{images_content_equal, run_scenario_experiment};
use layerjet::builder::{BuildOptions, CostModel};
use layerjet::daemon::Daemon;
use layerjet::inject::{InjectMode, InjectOptions};
use layerjet::registry::RemoteRegistry;
use layerjet::workload::{Scenario, ScenarioKind};
use std::path::PathBuf;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lj-e2e-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// All four scenarios run 2 trials end-to-end and stay verifiable.
#[test]
fn all_scenarios_run_end_to_end() {
    let root = tmp("all");
    for kind in ScenarioKind::ALL {
        let exp = run_scenario_experiment(
            kind,
            2,
            &root.join(kind.name()),
            CostModel::instant(),
            InjectMode::Implicit,
            11,
        )
        .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        assert_eq!(exp.docker.len(), 2);
        assert_eq!(exp.proposed.len(), 2);
    }
    std::fs::remove_dir_all(&root).unwrap();
}

/// Work accounting, scenario 2: the docker rebuild re-archives the big
/// dependency layers on every revision; the injection path's work is
/// bounded by the source change.
#[test]
fn scenario2_work_accounting() {
    let root = tmp("work");
    let cost = CostModel::instant();
    let mut docker = Daemon::new(&root.join("docker")).unwrap();
    let mut inject = Daemon::new(&root.join("inject")).unwrap();
    docker.cost = cost;
    inject.cost = cost;
    let mut scenario = Scenario::generate(ScenarioKind::PythonLarge, &root.join("p"), 3).unwrap();
    let tag = scenario.tag();
    let opts = BuildOptions { no_cache: false, cost, jobs: 1 };
    docker.build_with(&scenario.dir, &tag, &opts).unwrap();
    inject.build_with(&scenario.dir, &tag, &opts).unwrap();

    scenario.revise().unwrap(); // +1000 lines

    let rebuild = docker.build_with(&scenario.dir, &tag, &opts).unwrap();
    let injection = inject
        .inject_with(
            &scenario.dir,
            &tag,
            &tag,
            &InjectOptions { cost, ..Default::default() },
        )
        .unwrap();

    // Docker re-archived the apt + conda layers (fall-through): tens of MiB.
    assert!(
        rebuild.bytes_written() > 10 << 20,
        "docker rebuild should re-archive the dependency layers: {}",
        rebuild.bytes_written()
    );
    assert!(rebuild.rebuilt_steps() >= 4, "fall-through must hit steps 2..n");

    // Injection spliced only the changed tail of the COPY layer; its
    // total hashing work is bounded by the (small) source layer, not by
    // the dependency layers docker re-archived.
    let p = &injection.patched[0];
    assert!(
        p.bytes_spliced < 1 << 20,
        "injection splice should be < 1 MiB: {}",
        p.bytes_spliced
    );
    let inject_hash_bytes = (p.chunks_rehashed as u64) * 4096;
    assert!(
        inject_hash_bytes * 50 < rebuild.bytes_written(),
        "injection work ({inject_hash_bytes} B hashed) must be orders below \
         docker's re-archive ({} B)",
        rebuild.bytes_written()
    );
    // And the two daemons converge to identical content.
    assert!(images_content_equal(&docker, &inject, &tag).unwrap());
    std::fs::remove_dir_all(&root).unwrap();
}

/// Scenario 4 (compiled): the cascade rebuild re-runs `mvn package`, so
/// injection buys nothing — the jar layer is rebuilt either way, and both
/// paths produce identical jars.
#[test]
fn scenario4_cascade_parity() {
    let root = tmp("s4");
    let cost = CostModel::instant();
    let mut docker = Daemon::new(&root.join("docker")).unwrap();
    let mut inject = Daemon::new(&root.join("inject")).unwrap();
    docker.cost = cost;
    inject.cost = cost;
    let mut scenario = Scenario::generate(ScenarioKind::JavaLarge, &root.join("p"), 4).unwrap();
    let tag = scenario.tag();
    let opts = BuildOptions { no_cache: false, cost, jobs: 1 };
    docker.build_with(&scenario.dir, &tag, &opts).unwrap();
    inject.build_with(&scenario.dir, &tag, &opts).unwrap();

    scenario.revise().unwrap();
    docker.build_with(&scenario.dir, &tag, &opts).unwrap();
    let report = inject
        .inject_with(
            &scenario.dir,
            &tag,
            &tag,
            &InjectOptions { cascade: true, cost, ..Default::default() },
        )
        .unwrap();
    let cascade = report.cascade.expect("cascade report");
    assert!(
        cascade.steps.iter().any(|s| s.instruction.contains("mvn package") && !s.cached),
        "compile layer must re-run in the cascade"
    );
    assert!(images_content_equal(&docker, &inject, &tag).unwrap());
    std::fs::remove_dir_all(&root).unwrap();
}

/// The redeployment story across two machines and a registry, on the
/// java-tiny scenario (war replacement).
#[test]
fn redeploy_war_via_registry() {
    let root = tmp("redeploy");
    let cost = CostModel::instant();
    let mut dev = Daemon::new(&root.join("dev")).unwrap();
    let mut prod = Daemon::new(&root.join("prod")).unwrap();
    dev.cost = cost;
    prod.cost = cost;
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    let mut scenario = Scenario::generate(ScenarioKind::JavaTiny, &root.join("p"), 5).unwrap();
    let tag = scenario.tag();
    dev.build_with(&scenario.dir, &tag, &BuildOptions { no_cache: false, cost, jobs: 1 })
        .unwrap();
    dev.push(&tag, &remote).unwrap();

    scenario.revise().unwrap(); // edit + out-of-image recompile
    dev.inject_with(
        &scenario.dir,
        &tag,
        &tag,
        &InjectOptions {
            clone_for_redeploy: true,
            cost,
            ..Default::default()
        },
    )
    .unwrap();
    dev.push(&tag, &remote).unwrap();
    prod.pull(&tag, &remote).unwrap();
    assert!(prod.verify_image(&tag).unwrap());
    std::fs::remove_dir_all(&root).unwrap();
}
