//! Correctness gate for multi-layer targeted injection: a multi-layer
//! inject + sub-DAG rebuild must be **bit-identical** to a full
//! from-scratch rebuild — same image id, same layer tars — while
//! executing only the union of the per-change cascades. Covers the
//! interleaved changed/unchanged pattern, a diamond-shaped dependency
//! pattern, config-edit adoption, and the no-fall-through property.

use layerjet::builder::{BuildOptions, CostModel};
use layerjet::daemon::Daemon;
use layerjet::inject::InjectOptions;
use std::path::{Path, PathBuf};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lj-minj-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn daemon(root: &Path) -> Daemon {
    let mut daemon = Daemon::new(root).unwrap();
    daemon.cost = CostModel::instant();
    daemon
}

fn write_ctx(dir: &Path, dockerfile: &str, files: &[(&str, &str)]) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join("Dockerfile"), dockerfile).unwrap();
    for (p, c) in files {
        let path = dir.join(p);
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, c).unwrap();
    }
}

fn inject_opts(cascade: bool) -> InjectOptions {
    InjectOptions {
        cascade,
        cost: CostModel::instant(),
        ..InjectOptions::default()
    }
}

fn build_opts() -> BuildOptions {
    BuildOptions {
        no_cache: false,
        cost: CostModel::instant(),
        jobs: 1,
    }
}

/// The acceptance property: the injected daemon's image must be
/// bit-identical — same image id, same layer tars — to a from-scratch
/// build of the same context in a pristine store.
fn assert_bit_identical_to_scratch(injected: &Daemon, ctx: &Path, tag: &str, scratch_root: &Path) {
    let scratch = daemon(scratch_root);
    let scratch_report = scratch.build_with(ctx, tag, &build_opts()).unwrap();
    let (inj_id, inj_img) = injected.image(tag).unwrap();
    assert_eq!(inj_id, scratch_report.image_id, "image ids must match");
    let (_, scratch_img) = scratch.image(tag).unwrap();
    assert_eq!(inj_img.layer_ids, scratch_img.layer_ids);
    assert_eq!(inj_img.diff_ids, scratch_img.diff_ids);
    for (a, b) in inj_img.layer_ids.iter().zip(&scratch_img.layer_ids) {
        assert_eq!(
            injected.layers.read_tar(a).unwrap(),
            scratch.layers.read_tar(b).unwrap(),
            "layer tar bytes must match"
        );
    }
    assert!(injected.verify_image(tag).unwrap());
}

/// Changes in layers i and j with an unchanged, *independent* layer
/// between them: the rebuild executes exactly the union of the two
/// cascades, and the intermediate layer is a cache hit.
#[test]
fn interleaved_changes_rebuild_only_the_cascade_union() {
    let root = tmp("interleaved");
    let ctx = root.join("ctx");
    // 0 FROM, 1 WORKDIR, 2 ADD pom (changed), 3 mvn resolve (cascade of 2),
    // 4 apt update (unchanged + independent), 5 ADD src (changed),
    // 6 mvn package (cascade of 2 and 5), 7 CMD.
    let df = "FROM ubuntu:latest\nWORKDIR /code\nADD pom.xml pom.xml\n\
              RUN [\"mvn\", \"dependency:resolve\"]\nRUN apt update\nADD src /code/src\n\
              RUN [\"mvn\", \"package\"]\nCMD [\"java\", \"-jar\", \"target/app.jar\"]\n";
    let pom_v1 = "<project><artifactId>app</artifactId><dependency><artifactId>gson</artifactId></dependency></project>";
    write_ctx(&ctx, df, &[("pom.xml", pom_v1), ("src/App.java", "class App {}")]);
    let dev = daemon(&root.join("dev"));
    dev.build(&ctx, "japp:v1").unwrap();

    // Edit both content layers (modifications only, so splices stay
    // byte-equivalent to sorted rebuilds).
    let pom_v2 = pom_v1.replace(
        "</project>",
        "<dependency><artifactId>slf4j</artifactId></dependency></project>",
    );
    std::fs::write(ctx.join("pom.xml"), &pom_v2).unwrap();
    std::fs::write(ctx.join("src/App.java"), "class App { int x; }").unwrap();

    let report = dev
        .inject_with(&ctx, "japp:v1", "japp:v1", &inject_opts(true))
        .unwrap();
    assert_eq!(report.patched.len(), 2, "both content layers patched in place");

    let cascade = report.cascade.as_ref().expect("cascade report");
    let rebuilt: Vec<usize> = cascade
        .steps
        .iter()
        .filter(|s| !s.cached && !s.adopted)
        .map(|s| s.step - 1)
        .collect();
    assert_eq!(rebuilt, vec![3, 6], "exactly the union of the two cascades");
    assert!(
        cascade.steps[4].cached,
        "the unchanged layer BETWEEN the two changes must stay a cache hit: {:?}",
        cascade.steps[4]
    );
    assert!(cascade.steps[2].cached && cascade.steps[5].cached, "patched layers hit");

    let acc = report.cascade_accounting.as_ref().expect("accounting");
    assert_eq!(acc.steps_invalidated, 2);
    assert_eq!(acc.steps_rebuilt, 2);
    assert_eq!(acc.steps_adopted, 0);
    assert_eq!(
        acc.seed_fallthrough_steps, 6,
        "rebuild-after-first-change would re-run steps 2..8"
    );
    // Per-change cascades: the pom edit feeds resolve and package; the
    // src edit feeds package only.
    assert!(acc.per_change.contains(&(2, vec![3, 6])));
    assert!(acc.per_change.contains(&(5, vec![6])));

    assert_bit_identical_to_scratch(&dev, &ctx, "japp:v1", &root.join("scratch"));

    // And the chain is repaired: a strict docker build right after is
    // fully cached (no fall-through debt left behind).
    let strict = dev.build(&ctx, "japp:v1").unwrap();
    assert_eq!(strict.rebuilt_steps(), 0, "{:?}", strict.steps);
    std::fs::remove_dir_all(&root).unwrap();
}

/// Diamond-shaped dependencies: FROM → {ADD pom, ADD src} → mvn package.
/// A change on one shoulder rebuilds the join point only; the other
/// shoulder stays cached; result bit-identical to scratch.
#[test]
fn diamond_dependency_rebuilds_join_only() {
    let root = tmp("diamond");
    let ctx = root.join("ctx");
    // 0 FROM, 1 WORKDIR, 2 ADD pom, 3 ADD src, 4 mvn package, 5 CMD.
    let df = "FROM ubuntu:latest\nWORKDIR /code\nADD pom.xml pom.xml\nADD src /code/src\n\
              RUN [\"mvn\", \"package\"]\nCMD [\"java\"]\n";
    write_ctx(
        &ctx,
        df,
        &[
            ("pom.xml", "<project><artifactId>app</artifactId><dependency><artifactId>gson</artifactId></dependency></project>"),
            ("src/App.java", "class App {}"),
        ],
    );
    let dev = daemon(&root.join("dev"));
    dev.build(&ctx, "dia:v1").unwrap();

    std::fs::write(ctx.join("src/App.java"), "class App { int answer = 42; }").unwrap();
    let report = dev
        .inject_with(&ctx, "dia:v1", "dia:v1", &inject_opts(true))
        .unwrap();
    assert_eq!(report.patched.len(), 1);
    let cascade = report.cascade.as_ref().expect("cascade report");
    assert!(!cascade.steps[4].cached, "join point (mvn package) rebuilds");
    assert!(
        cascade.steps[2].cached,
        "the untouched diamond shoulder (ADD pom.xml) stays cached"
    );
    assert!(cascade.steps[3].cached, "the patched shoulder hits by source checksum");
    assert_eq!(report.cascade_accounting.as_ref().unwrap().steps_rebuilt, 1);

    assert_bit_identical_to_scratch(&dev, &ctx, "dia:v1", &root.join("scratch"));
    std::fs::remove_dir_all(&root).unwrap();
}

/// A config (type-2) edit in the middle of the Dockerfile: downstream
/// layer ids shift with the literal chain, but clean steps are adopted
/// byte-for-byte instead of re-executing — and the result is still
/// bit-identical to a scratch build.
#[test]
fn config_edit_adopts_downstream_layers() {
    let root = tmp("cfg-adopt");
    let ctx = root.join("ctx");
    // 0 FROM, 1 EXPOSE (edited), 2 COPY app, 3 RUN pip, 4 CMD. The COPY
    // imports a subdirectory, so the Dockerfile edit is config-only.
    let df_v1 = "FROM python:alpine\nEXPOSE 8080\nCOPY app /srv/app/\nRUN pip install flask\nCMD [\"python\"]\n";
    write_ctx(&ctx, df_v1, &[("app/main.py", "print('v1')\n")]);
    let dev = daemon(&root.join("dev"));
    dev.build(&ctx, "cfg:v1").unwrap();

    std::fs::write(ctx.join("Dockerfile"), df_v1.replace("8080", "9090")).unwrap();
    let report = dev
        .inject_with(&ctx, "cfg:v1", "cfg:v1", &inject_opts(false))
        .unwrap();
    assert!(report.delegated_to_build, "type-2 edit delegates to the engine");
    assert!(report.patched.is_empty(), "nothing to patch, nothing patched");
    let cascade = report.cascade.as_ref().expect("cascade report");
    assert_eq!(cascade.rebuilt_steps(), 1, "only the edited (empty) config layer");
    assert_eq!(cascade.adopted_steps(), 3, "COPY, RUN and CMD adopt under shifted ids");
    assert!(cascade.steps[2].adopted && cascade.steps[3].adopted && cascade.steps[4].adopted);

    let (_, img) = dev.image("cfg:v1").unwrap();
    assert!(img.config.exposed_ports.contains(&9090));
    assert_bit_identical_to_scratch(&dev, &ctx, "cfg:v1", &root.join("scratch"));
    std::fs::remove_dir_all(&root).unwrap();
}

/// The headline no-fall-through property: an edit in an early COPY layer
/// with an *independent* RUN after it leaves the RUN cached, and leaves
/// no fall-through debt for the next strict build — where the seed
/// behavior re-ran everything after the first change.
#[test]
fn unrelated_edit_leaves_no_fallthrough_debt() {
    let root = tmp("no-fall");
    let ctx = root.join("ctx");
    // 0 FROM, 1 COPY srcA (changed), 2 RUN pip (independent),
    // 3 COPY srcB (unchanged), 4 CMD.
    let df = "FROM python:alpine\nCOPY srcA /srv/a/\nRUN pip install flask\nCOPY srcB /srv/b/\nCMD [\"python\"]\n";
    write_ctx(
        &ctx,
        df,
        &[("srcA/main.py", "print('a1')\n"), ("srcB/util.py", "print('b1')\n")],
    );
    let dev = daemon(&root.join("dev"));
    dev.build(&ctx, "nf:v1").unwrap();

    std::fs::write(ctx.join("srcA/main.py"), "print('a2')\n").unwrap();
    let report = dev
        .inject_with(&ctx, "nf:v1", "nf:v1", &inject_opts(false))
        .unwrap();
    assert_eq!(report.patched.len(), 1);
    assert!(report.cascade.is_none(), "nothing downstream to rebuild");
    let acc = report.cascade_accounting.as_ref().expect("accounting");
    assert_eq!(acc.steps_invalidated, 0);
    assert_eq!(acc.steps_rebuilt, 0);
    assert_eq!(acc.seed_fallthrough_steps, 4, "the seed would have re-run steps 1..5");

    // The next strict build sees a fully intact cache chain: zero
    // rebuilds, where the seed's in-place patch left ParentChanged
    // fall-through debt on every later step.
    let strict = dev.build(&ctx, "nf:v1").unwrap();
    assert_eq!(strict.rebuilt_steps(), 0, "{:?}", strict.steps);

    assert_bit_identical_to_scratch(&dev, &ctx, "nf:v1", &root.join("scratch"));
    std::fs::remove_dir_all(&root).unwrap();
}

/// Adds and removes splice sorted, so even a file-set change stays
/// bit-identical to the scratch rebuild.
#[test]
fn add_and_remove_stay_bit_identical() {
    let root = tmp("addrm");
    let ctx = root.join("ctx");
    let df = "FROM python:alpine\nCOPY srcA /srv/a/\nCOPY srcB /srv/b/\nCMD [\"python\"]\n";
    write_ctx(
        &ctx,
        df,
        &[
            ("srcA/main.py", "print('a1')\n"),
            ("srcA/old.py", "gone\n"),
            ("srcB/util.py", "print('b1')\n"),
        ],
    );
    let dev = daemon(&root.join("dev"));
    dev.build(&ctx, "ar:v1").unwrap();

    std::fs::remove_file(ctx.join("srcA/old.py")).unwrap();
    std::fs::write(ctx.join("srcA/fresh.py"), "print('new')\n").unwrap();
    std::fs::write(ctx.join("srcB/util.py"), "print('b2')\n").unwrap();
    let report = dev
        .inject_with(&ctx, "ar:v1", "ar:v1", &inject_opts(false))
        .unwrap();
    assert_eq!(report.patched.len(), 2);
    assert_bit_identical_to_scratch(&dev, &ctx, "ar:v1", &root.join("scratch"));
    std::fs::remove_dir_all(&root).unwrap();
}
