//! Multi-writer lease acceptance tests: two registry handles on one
//! remote tree model two machines in a push fleet. A gc racing live
//! pushes must never collect a chunk a committed manifest references
//! (the exclusive maintenance lease waits out shared pusher leases),
//! and a zombie pusher — one whose lease expired and was fenced by a
//! later maintenance pass — must fail cleanly, with its stranded push
//! journal garbage-collected once gc reclaims its chunks.
//!
//! Fault plans are scoped to each test's temp root, matching the
//! conventions of `tests/faults.rs`.

use layerjet::fault::{self, FaultMode, FaultPlan};
use layerjet::prelude::*;
use layerjet::registry::{lease, LeaseConfig, PullOptions, PushOptions};
use layerjet::util::prng::Prng;
use std::path::{Path, PathBuf};
use std::time::Duration;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lj-leases-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn daemon(root: &Path) -> layerjet::Result<Daemon> {
    let mut daemon = Daemon::new(root)?;
    daemon.cost = CostModel::instant();
    Ok(daemon)
}

/// A small three-layer project; `salt` varies the COPY payload so each
/// salted project commits distinct layers and chunks (the base image
/// layers still dedup across them, as they would in a real fleet).
fn write_project(dir: &Path, salt: u64) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("Dockerfile"),
        "FROM python:alpine\nCOPY . /srv/\nRUN pip install flask\nCMD [\"python\", \"app.py\"]\n",
    )
    .unwrap();
    let mut asset = vec![0u8; 24 * 1024];
    Prng::new(0x1ea5e ^ salt).fill_bytes(&mut asset);
    std::fs::write(dir.join("asset.bin"), &asset).unwrap();
    std::fs::write(dir.join("app.py"), format!("print('leased {salt}')\n")).unwrap();
}

/// The headline race: one handle pushes a stream of images while a
/// second handle hammers scrub + gc. The exclusive maintenance lease
/// must serialize against the shared pusher leases, so every pushed tag
/// still pulls and verifies afterwards — no live-manifest chunk was
/// ever collected out from under a push.
#[test]
fn concurrent_push_and_gc_preserve_every_live_manifest() {
    let root = tmp("race");
    let tags: Vec<String> = (0..4).map(|i| format!("app:v{i}")).collect();
    let dev = daemon(&root.join("dev")).unwrap();
    for (i, tag) in tags.iter().enumerate() {
        let proj = root.join(format!("proj-{i}"));
        write_project(&proj, i as u64);
        dev.build(&proj, tag).unwrap();
    }

    let remote_dir = root.join("remote");
    let pusher = RemoteRegistry::open_with(
        &remote_dir,
        LeaseConfig { holder: "pusher-a".into(), ..Default::default() },
    )
    .unwrap();
    let sweeper = RemoteRegistry::open_with(
        &remote_dir,
        LeaseConfig { holder: "sweeper-b".into(), ..Default::default() },
    )
    .unwrap();

    std::thread::scope(|scope| {
        let push = scope.spawn(|| -> layerjet::Result<()> {
            for tag in &tags {
                dev.push_with(tag, &pusher, &PushOptions { jobs: 1, ..Default::default() })?;
            }
            Ok(())
        });
        let sweep = scope.spawn(|| -> layerjet::Result<()> {
            for _ in 0..8 {
                sweeper.scrub()?;
                sweeper.gc()?;
            }
            Ok(())
        });
        push.join().unwrap().expect("pushes must succeed under concurrent maintenance");
        sweep.join().unwrap().expect("maintenance must succeed under concurrent pushes");
    });

    let prod = daemon(&root.join("prod")).unwrap();
    for tag in &tags {
        prod.pull_with(tag, &pusher, &PullOptions { jobs: 1, ..Default::default() })
            .unwrap_or_else(|e| panic!("pull of {tag} after racing gc failed: {e:?}"));
        assert!(prod.verify_image(tag).unwrap(), "{tag} must verify after racing gc");
    }
    std::fs::remove_dir_all(&root).unwrap();
}

/// The zombie-pusher story end to end: a push dies at the commit point
/// (chunks pooled, journal written, lease record stranded), recovery
/// reclaims the expired lease, gc collects the uncommitted chunks, and
/// the next recovery garbage-collects the now-unresumable journal. A
/// fresh push then out-tokens the fence and completes normally.
#[test]
fn fenced_zombie_journal_is_garbage_collected_after_gc() {
    let root = tmp("zombie");
    let proj = root.join("proj");
    write_project(&proj, 99);
    let dev = daemon(&root.join("dev")).unwrap();
    dev.build(&proj, "app:v1").unwrap();

    let remote_dir = root.join("remote");
    // A zero ttl makes every grant expire the instant it is issued, so
    // the admin handle below reclaims the zombie without waiting out a
    // wall-clock heartbeat window.
    let remote = RemoteRegistry::open_with(
        &remote_dir,
        LeaseConfig { holder: "zombie".into(), ttl: Duration::ZERO, ..Default::default() },
    )
    .unwrap();

    // Kill the push at the first serial commit write. The crash-classed
    // error deliberately strands the shared lease record: a real dead
    // process would not have released either.
    let guard = fault::install(
        FaultPlan::fail_at("registry.push.commit", 0, FaultMode::Crash).scoped(&root),
    );
    let crashed = dev.push_with("app:v1", &remote, &PushOptions { jobs: 1, ..Default::default() });
    drop(guard);
    assert!(crashed.is_err(), "a commit crash must surface as an error");
    let lease_dir = remote_dir.join(lease::LEASE_DIR);
    assert!(
        std::fs::read_dir(&lease_dir)
            .unwrap()
            .any(|e| lease::is_record_name(&e.unwrap().file_name().to_string_lossy())),
        "the crashed pusher's lease record must survive for ttl reclaim"
    );

    // The admin handle's implicit recovery reclaims the expired lease
    // but keeps the journal: its chunks are all still pooled, so at this
    // point the push could legitimately resume.
    let admin = RemoteRegistry::open_with(
        &remote_dir,
        LeaseConfig { holder: "admin".into(), ttl: Duration::ZERO, ..Default::default() },
    )
    .unwrap();
    let opened = admin.open_recovery();
    assert!(opened.leases_reclaimed >= 1, "expired lease must be reclaimed at open: {opened:?}");
    assert_eq!(opened.journals_kept, 1, "pooled-complete journal stays resumable: {opened:?}");

    // gc finds nothing tagged and collects the zombie's chunks; from
    // here its journal can never resume.
    let gc = admin.gc().unwrap();
    assert!(gc.chunks_dropped >= 1, "gc must collect the uncommitted chunks: {gc:?}");
    let rec = admin.recover().unwrap();
    assert_eq!(rec.journals_dropped, 1, "chunk-less journal must be dropped: {rec:?}");
    assert_eq!(rec.journals_kept, 0, "{rec:?}");
    let leftover = std::fs::read_dir(remote_dir.join("push-journal"))
        .map(|it| it.count())
        .unwrap_or(0);
    assert_eq!(leftover, 0, "the zombie's journal directory must be gone");

    // The fence left by gc's exclusive lease never blocks new work: a
    // fresh grant's token always exceeds it.
    dev.push_with("app:v1", &remote, &PushOptions { jobs: 1, ..Default::default() })
        .expect("a fresh push must out-token the maintenance fence");
    let prod = daemon(&root.join("prod")).unwrap();
    prod.pull_with("app:v1", &remote, &PullOptions { jobs: 1, ..Default::default() }).unwrap();
    assert!(prod.verify_image("app:v1").unwrap());
    std::fs::remove_dir_all(&root).unwrap();
}

/// Fencing at the lease-API level, against a real registry's lease
/// table: a maintenance pass (scrub takes the exclusive lease) reclaims
/// an expired shared grant and fences its holder — validation and the
/// renew heartbeat both fail from then on — while new grants out-token
/// the fence and proceed.
#[test]
fn maintenance_fences_out_an_expired_pusher() {
    let root = tmp("fence");
    let remote_dir = root.join("remote");
    let admin = RemoteRegistry::open_with(
        &remote_dir,
        LeaseConfig { holder: "admin".into(), ..Default::default() },
    )
    .unwrap();

    let cfg = LeaseConfig {
        holder: "slow-pusher".into(),
        ttl: Duration::ZERO,
        acquire_timeout: Duration::from_millis(500),
        ..Default::default()
    };
    let lease_dir = remote_dir.join(lease::LEASE_DIR);
    let mut stale = lease::acquire(&lease_dir, lease::LeaseKind::Shared, &cfg).unwrap();
    // Wall-clock expiry alone does not invalidate a grant (clocks skew);
    // only an actual reclaim does.
    assert!(stale.validate().is_ok(), "an unreclaimed grant validates even past expiry");

    admin.scrub().unwrap();
    assert!(stale.validate().is_err(), "a fenced-out holder must fail validation");
    assert!(stale.renew().is_err(), "a fenced-out holder must fail its heartbeat");

    let fresh = lease::acquire(&lease_dir, lease::LeaseKind::Shared, &cfg).unwrap();
    assert!(fresh.token() > stale.token(), "tokens stay monotonic across the fence");
    fresh.release().unwrap();
    std::fs::remove_dir_all(&root).unwrap();
}

/// Pre-lease deployments keep working untouched: a legacy remote never
/// grows a lease table, writers skip the protocol entirely, and the
/// push/pull round-trip still verifies.
#[test]
fn legacy_remotes_stay_lease_unaware() {
    let root = tmp("legacy");
    let proj = root.join("proj");
    write_project(&proj, 7);
    let dev = daemon(&root.join("dev")).unwrap();
    dev.build(&proj, "app:v1").unwrap();

    let remote = RemoteRegistry::open_legacy(&root.join("remote")).unwrap();
    assert!(!remote.supports_leases(), "legacy layout must not be lease-capable");
    dev.push_with("app:v1", &remote, &PushOptions { jobs: 1, ..Default::default() }).unwrap();
    assert!(
        !root.join("remote").join(lease::LEASE_DIR).exists(),
        "pushing must not create a lease table on a legacy remote"
    );

    let prod = daemon(&root.join("prod")).unwrap();
    prod.pull_with("app:v1", &remote, &PullOptions { jobs: 1, ..Default::default() }).unwrap();
    assert!(prod.verify_image("app:v1").unwrap());
    std::fs::remove_dir_all(&root).unwrap();
}
