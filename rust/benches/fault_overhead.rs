//! **Robustness / fault hooks** — fault-free overhead of the injection
//! harness on the durable-write path.
//!
//! Every durability boundary routes writes through
//! `fault::durable_write`, whose disarmed fast path is a single relaxed
//! atomic load before the real create + write + fsync. This bench pins
//! that claim: the hooked path must sit within noise of the plain write,
//! in all three states a production process can see —
//!
//! * `plain`      — `durable_write_plain`, no hook at all (baseline);
//! * `disarmed`   — hooked, no plan installed (the production state);
//! * `foreign`    — hooked, a plan armed but scoped to a different tree
//!                  (the worst fault-free case: the slow path runs, the
//!                  scope filter rejects before any hit is counted).
//!
//! A fourth leg times the uncontended shared-lease cycle
//! (acquire → renew → release) that every fleet push pays against the
//! registry's lease table, bounded loosely against the single-write
//! baseline.
//!
//! `cargo bench --bench fault_overhead`

mod common;

use layerjet::fault::{self, FaultMode, FaultPlan};
use layerjet::registry::lease::{self, LeaseConfig, LeaseKind};
use std::path::Path;
use std::time::Instant;

/// Write + rename cycles mirroring `store::write_atomic`, returning mean
/// seconds per operation.
fn time_writes(dir: &Path, iters: usize, mut write: impl FnMut(&Path, &Path)) -> f64 {
    let target = dir.join("payload.bin");
    let tmp = dir.join("payload.bin.tmp-bench");
    // Warm the page cache / dentry path before timing.
    for _ in 0..iters / 10 + 1 {
        write(&target, &tmp);
        std::fs::rename(&tmp, &target).unwrap();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        write(&target, &tmp);
        std::fs::rename(&tmp, &target).unwrap();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let iters = common::trials(400);
    let root = common::bench_root("fault-overhead");
    std::fs::create_dir_all(&root).unwrap();
    let payload = vec![0xa5u8; 4096];

    // Leg 1: the unhooked baseline.
    let dir = root.join("plain");
    std::fs::create_dir_all(&dir).unwrap();
    let plain = time_writes(&dir, iters, |_, tmp| {
        fault::durable_write_plain(tmp, &payload).unwrap();
    });

    // Leg 2: hooked, disarmed — the production state.
    let dir = root.join("disarmed");
    std::fs::create_dir_all(&dir).unwrap();
    let disarmed = time_writes(&dir, iters, |target, tmp| {
        fault::durable_write("store.chunk.put", target, tmp, &payload).unwrap();
    });

    // Leg 3: hooked, armed, but scoped to a tree we never touch — the
    // slow path runs and the scope filter rejects every arrival.
    let elsewhere = root.join("elsewhere");
    let guard = fault::install(
        FaultPlan::fail_at("store.chunk.put", 0, FaultMode::Crash).scoped(&elsewhere),
    );
    let dir = root.join("foreign");
    std::fs::create_dir_all(&dir).unwrap();
    let foreign = time_writes(&dir, iters, |target, tmp| {
        fault::durable_write("store.chunk.put", target, tmp, &payload).unwrap();
    });
    drop(guard);

    // The check-only hook (negotiation, step entry, chunk reads) has no
    // I/O to hide behind; time it raw, disarmed.
    let probes = 4_000_000usize;
    let probe_path = root.join("probe");
    let t0 = Instant::now();
    for _ in 0..probes {
        fault::check("builder.step", &probe_path).unwrap();
    }
    let check_ns = t0.elapsed().as_secs_f64() * 1e9 / probes as f64;

    // Leg 4: the full shared-lease cycle a fleet pusher pays per push —
    // acquire (guard + seq + record) → renew heartbeat → release —
    // disarmed. This is several durable writes plus a lockfile, so it is
    // timed against its own loose bound rather than the single-write
    // legs above.
    let lease_dir = root.join("lease-table");
    let lease_cfg = LeaseConfig { holder: "bench".into(), ..Default::default() };
    let lease_iters = (iters / 4).max(25);
    let t0 = Instant::now();
    for _ in 0..lease_iters {
        let mut l = lease::acquire(&lease_dir, LeaseKind::Shared, &lease_cfg).unwrap();
        l.renew().unwrap();
        l.release().unwrap();
    }
    let lease_cycle = t0.elapsed().as_secs_f64() / lease_iters as f64;

    let ns = |s: f64| s * 1e9;
    eprintln!("fault-free durable write, {iters} iters of 4 KiB write+fsync+rename:");
    eprintln!("  plain            {:>10.0} ns/op", ns(plain));
    eprintln!("  hooked disarmed  {:>10.0} ns/op  ({:.3}x plain)", ns(disarmed), disarmed / plain);
    eprintln!("  hooked foreign   {:>10.0} ns/op  ({:.3}x plain)", ns(foreign), foreign / plain);
    eprintln!("  bare check()     {:>10.2} ns/op  (disarmed, no I/O)", check_ns);
    eprintln!(
        "  lease cycle      {:>10.0} ns/op  ({:.3}x plain; acquire+renew+release, {lease_iters} iters)",
        ns(lease_cycle),
        lease_cycle / plain
    );

    common::write_csv(
        "fault_overhead.csv",
        &format!(
            "leg,ns_per_op,vs_plain\nplain,{:.0},1.0\ndisarmed,{:.0},{:.4}\nforeign,{:.0},{:.4}\ncheck_disarmed,{:.2},\nlease_cycle,{:.0},{:.4}\n",
            ns(plain),
            ns(disarmed),
            disarmed / plain,
            ns(foreign),
            foreign / plain,
            check_ns,
            ns(lease_cycle),
            lease_cycle / plain,
        ),
    );

    // The acceptance claim: hooks are free when no fault is injected.
    // fsync dominates the write path, so even a generous bound would
    // only trip on a real regression (e.g. taking a lock on the fast
    // path).
    assert!(
        disarmed <= plain * 3.0,
        "disarmed fault hook must be within noise of the plain write \
         ({:.0} ns vs {:.0} ns)",
        ns(disarmed),
        ns(plain)
    );
    assert!(
        foreign <= plain * 3.0,
        "an armed-but-foreign-scope plan must not tax fault-free writes \
         ({:.0} ns vs {:.0} ns)",
        ns(foreign),
        ns(plain)
    );
    assert!(
        check_ns < 1000.0,
        "the disarmed check() hook must stay in the nanosecond regime ({check_ns:.1} ns)"
    );
    // A lease cycle is ~4 durable writes + a guard lockfile round-trip;
    // the bound is deliberately loose — it exists to catch a protocol
    // regression (e.g. an accidental poll loop on the uncontended path),
    // not to pin fsync timing.
    assert!(
        lease_cycle <= plain * 20.0,
        "an uncontended lease cycle must stay within a small multiple of one \
         durable write ({:.0} ns vs {:.0} ns)",
        ns(lease_cycle),
        ns(plain)
    );

    let _ = std::fs::remove_dir_all(&root);
}
