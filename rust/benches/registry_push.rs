//! **Chunk-addressed registry transport** — redeploy dedup ratio and
//! pipelined push throughput, with a machine-readable baseline
//! (`BENCH_registry_push.json`) so later transport PRs have a
//! trajectory to beat.
//!
//! Three experiments:
//! * **dedup** — build, push, then repeatedly one-line clone-inject and
//!   re-push: the wire bytes per redeploy vs the COPY layer's size (the
//!   paper's O(size-of-change) claim applied to the redeploy loop);
//! * **shifted insert** — insert one line near the TOP of the COPY
//!   payload (shifting every downstream tar byte) and re-push under
//!   both wire formats: content-defined chunking must stay O(change)
//!   (< 10% of the layer) where the fixed 4 KiB grid re-uploads the
//!   shifted bulk — the headline number fixed chunking cannot hit;
//! * **pipeline** — wall time of a cold multi-layer push at 1/2/4/8
//!   transport workers, against fresh remotes so dedup can't flatter
//!   the higher jobs levels.
//!
//! `cargo bench --bench registry_push` (set `LAYERJET_TRIALS` to
//! override the trial count).

mod common;

use layerjet::bench::report::{fmt_secs, Table};
use layerjet::bench::time_trials;
use layerjet::builder::CostModel;
use layerjet::daemon::Daemon;
use layerjet::inject::InjectOptions;
use layerjet::registry::{PushOptions, RemoteRegistry};
use layerjet::stats::summarize;
use layerjet::util::json::Json;
use layerjet::util::prng::Prng;
use std::path::Path;

const JOBS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let n = common::trials(8);
    let root = common::bench_root("registry-push");
    let (layer_bytes, mean_uploaded) = dedup_sweep(&root, n);
    let shifted = shifted_insert_sweep(&root, n);
    let pipeline = pipeline_sweep(&root, n);
    emit_baseline(n, layer_bytes, mean_uploaded, &shifted, &pipeline);

    // Shape assertions (the transport's acceptance bars): a one-line
    // append-redeploy must upload under 25% of the layer, and a
    // shifted INSERT under 10% — pure protocol properties, independent
    // of the machine's core count. The second is the one fixed-offset
    // chunking cannot satisfy (its control leg re-uploads the bulk).
    let fraction = mean_uploaded / layer_bytes as f64;
    assert!(
        fraction < 0.25,
        "one-line redeploy uploaded {:.1}% of the layer — chunk negotiation regressed",
        fraction * 100.0
    );
    assert!(
        shifted.cdc_fraction < 0.10,
        "shifted insert uploaded {:.1}% of the layer under CDC — shift robustness regressed",
        shifted.cdc_fraction * 100.0
    );
    eprintln!(
        "registry_push shape checks OK ({:.2}% per append redeploy, {:.2}% per shifted insert; \
         fixed-chunk control {:.1}%)",
        fraction * 100.0,
        shifted.cdc_fraction * 100.0,
        shifted.fixed_fraction * 100.0
    );
    let _ = std::fs::remove_dir_all(&root);
}

/// Shifted-insert accounting: mean upload fraction of the COPY layer
/// under the CDC (v2) and fixed-chunk (v1) wire formats.
struct ShiftedInsert {
    cdc_fraction: f64,
    fixed_fraction: f64,
    cdc_mean_uploaded: f64,
    fixed_mean_uploaded: f64,
}

/// Insert one line near the top of the dominant asset each trial (every
/// downstream tar byte shifts), clone-inject, and push under both wire
/// formats against separate remotes.
fn shifted_insert_sweep(root: &Path, n: usize) -> ShiftedInsert {
    let proj = root.join("shift-proj");
    write_project(&proj, 2 << 20, 1);
    let mut dev = Daemon::new(&root.join("shift-daemon")).unwrap();
    dev.cost = CostModel::instant();
    dev.build(&proj, "sbench:v0").unwrap();
    let cdc_remote = RemoteRegistry::open(&root.join("shift-remote-cdc")).unwrap();
    let fixed_remote = RemoteRegistry::open(&root.join("shift-remote-fixed")).unwrap();
    dev.push("sbench:v0", &cdc_remote).unwrap();
    dev.push_with(
        "sbench:v0",
        &fixed_remote,
        &PushOptions { manifest_v1: true, ..Default::default() },
    )
    .unwrap();

    let mut cdc_fractions = Vec::new();
    let mut fixed_fractions = Vec::new();
    let mut cdc_uploaded = Vec::new();
    let mut fixed_uploaded = Vec::new();
    for trial in 0..n {
        let asset_path = proj.join("part0/aa_assets.bin");
        let asset = std::fs::read(&asset_path).unwrap();
        let line = format!("# inserted line, rev {trial}\n");
        let mut shifted = Vec::with_capacity(asset.len() + line.len());
        shifted.extend_from_slice(&asset[..97]);
        shifted.extend_from_slice(line.as_bytes());
        shifted.extend_from_slice(&asset[97..]);
        std::fs::write(&asset_path, &shifted).unwrap();
        let from = if trial == 0 { "sbench:v0".into() } else { format!("sbench:v{trial}") };
        let to = format!("sbench:v{}", trial + 1);
        dev.inject_with(
            &proj,
            &from,
            &to,
            &InjectOptions {
                clone_for_redeploy: true,
                cost: CostModel::instant(),
                ..Default::default()
            },
        )
        .unwrap();
        let (_, img) = dev.image(&to).unwrap();
        let layer_bytes = dev.layers.read_tar(&img.layer_ids[1]).unwrap().len() as f64;
        let cdc = dev.push(&to, &cdc_remote).unwrap();
        let fixed = dev
            .push_with(&to, &fixed_remote, &PushOptions { manifest_v1: true, ..Default::default() })
            .unwrap();
        cdc_fractions.push(cdc.bytes_uploaded as f64 / layer_bytes);
        fixed_fractions.push(fixed.bytes_uploaded as f64 / layer_bytes);
        cdc_uploaded.push(cdc.bytes_uploaded as f64);
        fixed_uploaded.push(fixed.bytes_uploaded as f64);
    }
    let out = ShiftedInsert {
        cdc_fraction: summarize(&cdc_fractions).mean,
        fixed_fraction: summarize(&fixed_fractions).mean,
        cdc_mean_uploaded: summarize(&cdc_uploaded).mean,
        fixed_mean_uploaded: summarize(&fixed_uploaded).mean,
    };

    let mut table = Table::new(
        &format!("one-line SHIFTED insert near the top of a ~2 MiB COPY layer ({n} trials)"),
        &["wire format", "mean wire bytes", "fraction of layer"],
    );
    table.row(vec![
        "v2 content-defined".into(),
        format!("{:.0}", out.cdc_mean_uploaded),
        format!("{:.2}%", 100.0 * out.cdc_fraction),
    ]);
    table.row(vec![
        "v1 fixed 4 KiB".into(),
        format!("{:.0}", out.fixed_mean_uploaded),
        format!("{:.1}%", 100.0 * out.fixed_fraction),
    ]);
    table.print();
    out
}

/// Build a project whose COPY layer is dominated by a deterministic
/// asset blob; the mutable source file sorts last so edits stay
/// chunk-local in the layer tar.
fn write_project(dir: &Path, asset_len: usize, layers: usize) {
    std::fs::create_dir_all(dir).unwrap();
    let mut df = String::from("FROM python:alpine\n");
    for l in 0..layers {
        df.push_str(&format!("COPY part{l} /srv/part{l}/\n"));
    }
    df.push_str("CMD [\"python\", \"main.py\"]\n");
    std::fs::write(dir.join("Dockerfile"), df).unwrap();
    let mut rng = Prng::new(0xd0cc);
    for l in 0..layers {
        let part = dir.join(format!("part{l}"));
        std::fs::create_dir_all(&part).unwrap();
        let mut asset = vec![0u8; asset_len];
        rng.fill_bytes(&mut asset);
        std::fs::write(part.join("aa_assets.bin"), &asset).unwrap();
        std::fs::write(part.join("zz_main.py"), "print('v1')\n").unwrap();
    }
}

/// Redeploy loop: one-line clone-inject then push. Returns the COPY
/// layer's tar size and the mean wire bytes per redeploy push.
fn dedup_sweep(root: &Path, n: usize) -> (u64, f64) {
    let proj = root.join("dedup-proj");
    write_project(&proj, 2 << 20, 1);
    let mut dev = Daemon::new(&root.join("dedup-daemon")).unwrap();
    dev.cost = CostModel::instant();
    dev.build(&proj, "rbench:v0").unwrap();
    let remote = RemoteRegistry::open(&root.join("dedup-remote")).unwrap();
    let seed = dev.push("rbench:v0", &remote).unwrap();

    let (_, img) = dev.image("rbench:v0").unwrap();
    let layer_bytes = dev.layers.read_tar(&img.layer_ids[1]).unwrap().len() as u64;

    let mut uploaded = Vec::new();
    for trial in 0..n {
        let main_path = proj.join("part0/zz_main.py");
        let main = std::fs::read_to_string(&main_path).unwrap();
        std::fs::write(&main_path, format!("{main}print('rev {trial}')\n")).unwrap();
        let from = if trial == 0 { "rbench:v0".into() } else { format!("rbench:v{trial}") };
        let to = format!("rbench:v{}", trial + 1);
        dev.inject_with(
            &proj,
            &from,
            &to,
            &InjectOptions {
                clone_for_redeploy: true,
                cost: CostModel::instant(),
                ..Default::default()
            },
        )
        .unwrap();
        let report = dev.push(&to, &remote).unwrap();
        uploaded.push(report.bytes_uploaded as f64);
    }
    let mean = summarize(&uploaded).mean;

    let mut table = Table::new(
        &format!("one-line redeploy push, {} KiB COPY layer ({n} trials)", layer_bytes >> 10),
        &["push", "wire bytes", "fraction of layer"],
    );
    table.row(vec![
        "initial".into(),
        seed.bytes_uploaded.to_string(),
        format!("{:.1}%", 100.0 * seed.bytes_uploaded as f64 / layer_bytes as f64),
    ]);
    table.row(vec![
        "redeploy (mean)".into(),
        format!("{mean:.0}"),
        format!("{:.2}%", 100.0 * mean / layer_bytes as f64),
    ]);
    table.print();
    (layer_bytes, mean)
}

/// Cold pushes of a multi-layer image at several transport widths.
/// Returns `(jobs, mean seconds)` per point.
fn pipeline_sweep(root: &Path, n: usize) -> Vec<(usize, f64)> {
    let proj = root.join("pipe-proj");
    write_project(&proj, 1 << 20, 6);
    let mut dev = Daemon::new(&root.join("pipe-daemon")).unwrap();
    dev.cost = CostModel::instant();
    dev.build(&proj, "pbench:v0").unwrap();

    let mut table = Table::new(
        &format!("cold push, 6 × 1 MiB COPY layers ({n} trials)"),
        &["jobs", "mean", "speedup vs 1"],
    );
    let mut out = Vec::new();
    let mut base = 0.0;
    for jobs in JOBS {
        let opts = PushOptions { jobs, ..Default::default() };
        let t = summarize(&time_trials(1, n, |trial| {
            // A fresh remote per push: measure the wire, not the dedup.
            let rdir = root.join(format!("pipe-remote-j{jobs}-{trial}"));
            let _ = std::fs::remove_dir_all(&rdir);
            let remote = RemoteRegistry::open(&rdir).unwrap();
            dev.push_with("pbench:v0", &remote, &opts).unwrap();
        }));
        if jobs == 1 {
            base = t.mean;
        }
        table.row(vec![
            jobs.to_string(),
            fmt_secs(t.mean),
            format!("{:.2}x", base / t.mean.max(1e-12)),
        ]);
        out.push((jobs, t.mean));
    }
    table.print();
    out
}

/// Write the machine-readable baseline: once into `bench_results/` and
/// once at the repository root (the trajectory file later transport PRs
/// compare against).
fn emit_baseline(
    n: usize,
    layer_bytes: u64,
    mean_uploaded: f64,
    shifted: &ShiftedInsert,
    pipeline: &[(usize, f64)],
) {
    let point = |(jobs, mean): &(usize, f64)| {
        Json::obj(vec![
            ("jobs", Json::num(*jobs as f64)),
            ("mean_s", Json::num(*mean)),
        ])
    };
    let speedup_4j = pipeline
        .iter()
        .find(|(j, _)| *j == 4)
        .map(|(_, m)| pipeline[0].1 / m.max(1e-12))
        .unwrap_or(f64::NAN);
    let doc = Json::obj(vec![
        ("bench", Json::str("registry_push")),
        ("measured", Json::Bool(true)),
        ("trials", Json::num(n as f64)),
        ("copy_layer_bytes", Json::num(layer_bytes as f64)),
        ("redeploy_mean_uploaded_bytes", Json::num(mean_uploaded)),
        (
            "redeploy_upload_fraction",
            Json::num(mean_uploaded / layer_bytes as f64),
        ),
        (
            "shifted_insert",
            Json::obj(vec![
                ("cdc_mean_uploaded_bytes", Json::num(shifted.cdc_mean_uploaded)),
                ("cdc_upload_fraction", Json::num(shifted.cdc_fraction)),
                ("fixed_mean_uploaded_bytes", Json::num(shifted.fixed_mean_uploaded)),
                ("fixed_upload_fraction", Json::num(shifted.fixed_fraction)),
            ]),
        ),
        ("push_cold", Json::Arr(pipeline.iter().map(point).collect())),
        ("push_speedup_4j", Json::num(speedup_4j)),
    ]);
    let text = doc.to_string_pretty();
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_registry_push.json", &text).expect("write baseline");
    // Repo root (cargo bench runs from the package dir `rust/`).
    if std::fs::write("../BENCH_registry_push.json", &text).is_ok() {
        eprintln!("wrote ../BENCH_registry_push.json");
    }
    eprintln!("wrote bench_results/BENCH_registry_push.json");
}
