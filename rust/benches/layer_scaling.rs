//! **E9 / §II.B** — rebuild cost vs layer size: Docker's rebuild is
//! O(layer size) while injection is O(change size) ("effectively
//! reducing the O(n), n = size of layer, rebuild time to O(1)").
//!
//! Sweeps the COPY payload from 512 KiB to 16 MiB with a constant
//! one-line edit and reports both times plus the chunk-rehash counts
//! that explain them.
//!
//! `cargo bench --bench layer_scaling`

mod common;

use layerjet::bench::report::{fmt_secs, Table};
use layerjet::builder::CostModel;
use layerjet::daemon::Daemon;
use layerjet::stats::summarize;
use layerjet::util::prng::Prng;

fn main() {
    let n = common::trials(10);
    let root = common::bench_root("scaling");
    let sizes_mib = [0.5f64, 1.0, 2.0, 4.0, 8.0, 16.0];

    let mut table = Table::new(
        &format!("§II.B — rebuild cost vs COPY layer size ({n} trials/point, 1-line edit)"),
        &["payload", "docker mean", "inject mean", "speedup", "chunks rehashed/total"],
    );
    let mut csv = String::from("payload_mib,docker_mean_s,inject_mean_s,speedup,chunks_rehashed,chunks_total\n");

    let mut prev_docker = 0.0;
    for (i, mib) in sizes_mib.iter().enumerate() {
        let bytes = (mib * 1048576.0) as usize;
        let case_root = root.join(format!("case-{i}"));
        let project = case_root.join("project");
        std::fs::create_dir_all(&project).unwrap();
        std::fs::write(
            project.join("Dockerfile"),
            "FROM python:alpine\nCOPY . /app/\nCMD [\"python\", \"app/main.py\"]\n",
        )
        .unwrap();
        // Payload: one big generated asset + the editable script.
        let mut rng = Prng::new(1000 + i as u64);
        let mut blob = vec![0u8; bytes];
        rng.fill_bytes(&mut blob);
        std::fs::write(project.join("assets.bin"), &blob).unwrap();
        std::fs::write(project.join("main.py"), "print('v0')\n").unwrap();

        let mut daemon_d = Daemon::new(&case_root.join("docker")).unwrap();
        let mut daemon_i = Daemon::new(&case_root.join("inject")).unwrap();
        daemon_d.cost = CostModel::default();
        daemon_i.cost = CostModel::default();
        daemon_d.build(&project, "scale:latest").unwrap();
        daemon_i.build(&project, "scale:latest").unwrap();

        let mut docker = Vec::new();
        let mut inject = Vec::new();
        let (mut rehashed, mut total) = (0usize, 0usize);
        for t in 0..n {
            let mut main = std::fs::read_to_string(project.join("main.py")).unwrap();
            main.push_str(&format!("print('edit {t}')\n"));
            std::fs::write(project.join("main.py"), main).unwrap();

            let t0 = std::time::Instant::now();
            daemon_d.build(&project, "scale:latest").unwrap();
            docker.push(t0.elapsed().as_secs_f64());

            let t0 = std::time::Instant::now();
            let r = daemon_i.inject(&project, "scale:latest", "scale:latest").unwrap();
            inject.push(t0.elapsed().as_secs_f64());
            rehashed = r.patched[0].chunks_rehashed;
            total = r.patched[0].chunks_total;
        }
        let d = summarize(&docker);
        let p = summarize(&inject);
        table.row(vec![
            format!("{mib} MiB"),
            fmt_secs(d.mean),
            fmt_secs(p.mean),
            format!("{:.1}x", d.mean / p.mean.max(1e-12)),
            format!("{rehashed}/{total}"),
        ]);
        csv.push_str(&format!(
            "{},{:.6},{:.6},{:.2},{},{}\n",
            mib,
            d.mean,
            p.mean,
            d.mean / p.mean.max(1e-12),
            rehashed,
            total
        ));

        // Shape: docker grows with payload; rehash count stays a small
        // fraction of the chunk count.
        if i > 1 {
            assert!(
                d.mean > prev_docker * 0.9,
                "docker time should not shrink as layers grow"
            );
        }
        assert!(
            rehashed * 4 < total.max(4),
            "inject must rehash a small fraction: {rehashed}/{total}"
        );
        prev_docker = d.mean;
    }
    table.print();
    common::write_csv("layer_scaling.csv", &csv);

    let _ = std::fs::remove_dir_all(&root);
    eprintln!("layer_scaling shape checks OK (O(n) docker vs O(change) inject)");
}
