//! **Multi-layer targeted injection** — k changed layers out of n, the
//! cascade-DAG path against the rebuild-after-first-change control leg,
//! with a machine-readable baseline (`BENCH_multi_inject.json`).
//!
//! The project is n independent COPY layers (no step consumes another's
//! output), so the DAG cascade of every edit is empty: the injection
//! path does O(k) layer patches and zero step re-executions, while the
//! Docker control leg — whose cache falls through linearly — re-executes
//! every step after the first change regardless of k.
//!
//! `cargo bench --bench multi_inject` (set `LAYERJET_TRIALS` to override
//! the trial count).

mod common;

use layerjet::bench::report::{fmt_secs, Table};
use layerjet::builder::BuildOptions;
use layerjet::daemon::Daemon;
use layerjet::inject::InjectOptions;
use layerjet::stats::summarize;
use layerjet::util::json::Json;
use layerjet::util::prng::Prng;
use std::path::Path;
use std::time::Instant;

/// COPY layers in the project (steps = n + FROM + CMD).
const N_PARTS: usize = 10;
/// Changed-layer counts swept per run.
const KS: [usize; 3] = [1, 3, 6];

struct Point {
    k: usize,
    control_steps_rebuilt: usize,
    cascade_steps_rebuilt: usize,
    patched_layers: usize,
    control_mean_s: f64,
    cascade_mean_s: f64,
}

fn main() {
    let n = common::trials(8);
    let root = common::bench_root("multi-inject");
    let mut points = Vec::new();
    for k in KS {
        points.push(sweep_k(&root, k, n));
    }

    let mut table = Table::new(
        &format!("k changed of {N_PARTS} COPY layers ({n} trials): cascade vs fall-through"),
        &["k", "control steps", "cascade steps", "control mean", "cascade mean", "speedup"],
    );
    for p in &points {
        table.row(vec![
            p.k.to_string(),
            p.control_steps_rebuilt.to_string(),
            p.cascade_steps_rebuilt.to_string(),
            fmt_secs(p.control_mean_s),
            fmt_secs(p.cascade_mean_s),
            format!("{:.1}x", p.control_mean_s / p.cascade_mean_s.max(1e-12)),
        ]);
    }
    table.print();
    emit_baseline(n, &points);

    // Shape assertions — pure work accounting, machine-independent: the
    // control leg falls through to the end while the cascade leg
    // re-executes nothing (the edits have no dependents).
    for p in &points {
        assert_eq!(
            p.control_steps_rebuilt, N_PARTS,
            "k={}: fall-through must rebuild every step after the first change",
            p.k
        );
        assert_eq!(
            p.cascade_steps_rebuilt, 0,
            "k={}: independent COPY edits must re-execute nothing",
            p.k
        );
        assert_eq!(p.patched_layers, p.k, "k={}: exactly k layers patched", p.k);
    }
    eprintln!("multi_inject shape checks OK");
    let _ = std::fs::remove_dir_all(&root);
}

/// Evenly spread k edited part indices starting at part 1 (so the
/// control leg's fall-through covers nearly the whole Dockerfile).
fn edited_parts(k: usize) -> Vec<usize> {
    (0..k).map(|i| 1 + i * (N_PARTS - 1) / k).collect()
}

fn write_project(dir: &Path) {
    std::fs::create_dir_all(dir).unwrap();
    let mut df = String::from("FROM python:alpine\n");
    for l in 0..N_PARTS {
        df.push_str(&format!("COPY part{l} /srv/part{l}/\n"));
    }
    df.push_str("CMD [\"python\", \"main.py\"]\n");
    std::fs::write(dir.join("Dockerfile"), df).unwrap();
    let mut rng = Prng::new(0xca5cade);
    for l in 0..N_PARTS {
        let part = dir.join(format!("part{l}"));
        std::fs::create_dir_all(&part).unwrap();
        let mut asset = vec![0u8; 256 << 10];
        rng.fill_bytes(&mut asset);
        std::fs::write(part.join("aa_assets.bin"), &asset).unwrap();
        std::fs::write(part.join("zz_main.py"), "print('v0')\n").unwrap();
    }
}

fn sweep_k(root: &Path, k: usize, trials: usize) -> Point {
    let proj = root.join(format!("proj-k{k}"));
    write_project(&proj);
    let control = Daemon::new(&root.join(format!("control-k{k}"))).unwrap();
    let inject = Daemon::new(&root.join(format!("inject-k{k}"))).unwrap();
    let build_opts = BuildOptions::default();
    let inject_opts = InjectOptions::default();
    let tag = "minj:v1";
    control.build_with(&proj, tag, &build_opts).unwrap();
    inject.build_with(&proj, tag, &build_opts).unwrap();

    let parts = edited_parts(k);
    let mut control_s = Vec::with_capacity(trials);
    let mut cascade_s = Vec::with_capacity(trials);
    let (mut control_rebuilt, mut cascade_rebuilt, mut patched) = (0usize, 0usize, 0usize);
    // One untimed warm-up revision, then the timed trials.
    for trial in 0..trials + 1 {
        for part in &parts {
            let path = proj.join(format!("part{part}/zz_main.py"));
            let text = std::fs::read_to_string(&path).unwrap();
            std::fs::write(&path, format!("{text}print('rev {trial}')\n")).unwrap();
        }

        let t0 = Instant::now();
        let control_report = control.build_with(&proj, tag, &build_opts).unwrap();
        let control_elapsed = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let inject_report = inject.inject_with(&proj, tag, tag, &inject_opts).unwrap();
        let cascade_elapsed = t0.elapsed().as_secs_f64();

        if trial == 0 {
            continue; // warm-up
        }
        control_s.push(control_elapsed);
        cascade_s.push(cascade_elapsed);
        control_rebuilt = control_report.rebuilt_steps();
        patched = inject_report.patched.len();
        cascade_rebuilt = inject_report
            .cascade_accounting
            .as_ref()
            .map(|a| a.steps_rebuilt)
            .unwrap_or(0);
    }
    Point {
        k,
        control_steps_rebuilt: control_rebuilt,
        cascade_steps_rebuilt: cascade_rebuilt,
        patched_layers: patched,
        control_mean_s: summarize(&control_s).mean,
        cascade_mean_s: summarize(&cascade_s).mean,
    }
}

/// Write the machine-readable baseline: once into `bench_results/` and
/// once at the repository root (the trajectory file later PRs compare
/// against).
fn emit_baseline(trials: usize, points: &[Point]) {
    let arr = points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("k_changed", Json::num(p.k as f64)),
                ("control_steps_rebuilt", Json::num(p.control_steps_rebuilt as f64)),
                ("cascade_steps_rebuilt", Json::num(p.cascade_steps_rebuilt as f64)),
                ("patched_layers", Json::num(p.patched_layers as f64)),
                ("control_mean_s", Json::num(p.control_mean_s)),
                ("cascade_mean_s", Json::num(p.cascade_mean_s)),
                (
                    "speedup",
                    Json::num(p.control_mean_s / p.cascade_mean_s.max(1e-12)),
                ),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("multi_inject")),
        ("measured", Json::Bool(true)),
        ("trials", Json::num(trials as f64)),
        ("n_copy_layers", Json::num(N_PARTS as f64)),
        ("points", Json::Arr(arr)),
    ]);
    let text = doc.to_string_pretty();
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_multi_inject.json", &text).expect("write baseline");
    if std::fs::write("../BENCH_multi_inject.json", &text).is_ok() {
        eprintln!("wrote ../BENCH_multi_inject.json");
    }
    eprintln!("wrote bench_results/BENCH_multi_inject.json");
}
