//! **Chunk-backed store economics** — what the layer-free `LayerStore`
//! costs on disk as an edit history grows, and what reconstructing a
//! tar from the pool costs at read time. Emits a machine-readable
//! baseline (`BENCH_dedup_store.json`).
//!
//! Two experiments:
//! * **history storage** — 50 one-file-edit revisions of a 1 MiB-asset
//!   layer; the pool must grow by the churn, not by the revision count
//!   (the acceptance bar: full history < 2x one revision's pool bytes,
//!   where a tar-per-layer layout pays the full 50x);
//! * **reconstruction latency** — cold `read_tar` (chunk reassembly
//!   from the pool on a fresh store handle) vs hot (the in-memory tar
//!   cache), bit-identity asserted on every read.
//!
//! `cargo bench --bench dedup_store` (set `LAYERJET_TRIALS` to
//! override the trial count).

mod common;

use layerjet::bench::report::{fmt_secs, Table};
use layerjet::hash::{ChunkDigest, Digest, NativeEngine};
use layerjet::oci::{LayerId, LayerMeta};
use layerjet::store::{LayerStore, LAYER_VERSION};
use layerjet::tar::TarBuilder;
use layerjet::util::json::Json;
use layerjet::util::prng::Prng;
use std::path::Path;

const REVISIONS: usize = 50;
const ASSET_LEN: usize = 1 << 20;
const CHECKPOINTS: [usize; 4] = [1, 10, 25, 50];

fn main() {
    let trials = common::trials(5).max(2);
    let root = common::bench_root("dedup-store");
    std::fs::create_dir_all(&root).unwrap();
    let history = history_sweep(&root);
    let recon = reconstruct_sweep(&root, trials);
    emit_baseline(&history, &recon, trials);

    // Shape assertions (protocol properties, not timing — safe on any
    // machine): the pool grows by churn, not by revision count, and
    // the whole store undercuts the tar-per-layer layout by a wide
    // margin.
    assert!(
        history.pool_bytes_full < 2 * history.pool_bytes_single,
        "{REVISIONS}-revision pool {} must stay < 2x one revision's {}",
        history.pool_bytes_full,
        history.pool_bytes_single
    );
    assert!(
        history.store_bytes_full < history.logical_bytes / 5,
        "store footprint {} must be well under {} logical bytes",
        history.store_bytes_full,
        history.logical_bytes
    );
    eprintln!(
        "dedup_store shape checks OK ({REVISIONS} revisions in {:.1}% of tar-per-layer bytes; \
         cold reconstruct {}, hot {})",
        history.store_bytes_full as f64 / history.logical_bytes as f64 * 100.0,
        fmt_secs(recon.cold_secs),
        fmt_secs(recon.hot_secs)
    );
    let _ = std::fs::remove_dir_all(&root);
}

struct HistoryStorage {
    logical_bytes: u64,
    pool_bytes_single: u64,
    pool_bytes_full: u64,
    store_bytes_full: u64,
    pool_chunks_full: u64,
}

struct ReconstructLatency {
    tar_bytes: u64,
    cold_secs: f64,
    hot_secs: f64,
}

/// One revision of the benched project layer: a constant random asset
/// plus a tiny source file that changes every revision, the asset
/// sorted first so the delta sits at the tar tail.
fn revision_layer(asset: &[u8], rev: usize) -> (LayerMeta, Vec<u8>) {
    let mut b = TarBuilder::new();
    b.append_file("aa_assets.bin", asset).unwrap();
    b.append_file("zz_main.py", format!("print('rev {rev}')\n").as_bytes()).unwrap();
    let tar = b.finish();
    let created_by = format!("COPY . /srv/ # rev {rev}");
    let id = LayerId::derive("bench", None, &created_by);
    let meta = LayerMeta {
        id,
        parent: None,
        parent_checksum: None,
        checksum: Digest::of(&tar),
        chunk_root: ChunkDigest::compute(&tar, &NativeEngine::new()).root,
        created_by,
        source_checksum: Digest([0u8; 32]),
        is_empty_layer: false,
        size: tar.len() as u64,
        version: LAYER_VERSION.into(),
    };
    (meta, tar)
}

/// Total bytes of every regular file under `root`.
fn disk_usage(root: &Path) -> u64 {
    fn walk(dir: &Path, total: &mut u64) {
        for e in std::fs::read_dir(dir).unwrap() {
            let e = e.unwrap();
            if e.file_type().unwrap().is_dir() {
                walk(&e.path(), total);
            } else {
                *total += e.metadata().unwrap().len();
            }
        }
    }
    let mut total = 0;
    walk(root, &mut total);
    total
}

/// Store `REVISIONS` one-file-edit revisions and record how the pool
/// and the whole store grow against the logical (tar-per-layer) cost.
fn history_sweep(root: &Path) -> HistoryStorage {
    let mut asset = vec![0u8; ASSET_LEN];
    Prng::new(0xd15c).fill_bytes(&mut asset);
    let eng = NativeEngine::new();
    let store_root = root.join("history");
    let store = LayerStore::open(&store_root).unwrap();

    let mut table = Table::new(
        &format!("{REVISIONS} one-file-edit revisions, {} KiB asset", ASSET_LEN / 1024),
        &["revisions", "logical", "pool", "on disk", "vs tar-per-layer"],
    );
    let mut out = HistoryStorage {
        logical_bytes: 0,
        pool_bytes_single: 0,
        pool_bytes_full: 0,
        store_bytes_full: 0,
        pool_chunks_full: 0,
    };
    for rev in 0..REVISIONS {
        let (meta, tar) = revision_layer(&asset, rev);
        store.put_layer(&meta, &tar, &eng).unwrap();
        out.logical_bytes += tar.len() as u64;
        if !CHECKPOINTS.contains(&(rev + 1)) {
            continue;
        }
        let st = store.stats().unwrap();
        let on_disk = disk_usage(&store_root);
        if rev == 0 {
            out.pool_bytes_single = st.pool_bytes;
        }
        out.pool_bytes_full = st.pool_bytes;
        out.store_bytes_full = on_disk;
        out.pool_chunks_full = st.pool_chunks as u64;
        table.row(vec![
            (rev + 1).to_string(),
            format!("{} KiB", out.logical_bytes / 1024),
            format!("{} KiB", st.pool_bytes / 1024),
            format!("{} KiB", on_disk / 1024),
            format!("{:.1}%", on_disk as f64 / out.logical_bytes as f64 * 100.0),
        ]);
    }
    table.print();
    out
}

/// Time `read_tar` cold (fresh store handle, full chunk reassembly)
/// and hot (in-memory tar cache), asserting bit-identity every read.
fn reconstruct_sweep(root: &Path, trials: usize) -> ReconstructLatency {
    let mut asset = vec![0u8; ASSET_LEN];
    Prng::new(0x7ea5e7).fill_bytes(&mut asset);
    let eng = NativeEngine::new();
    let store_root = root.join("reconstruct");
    let (meta, tar) = revision_layer(&asset, 0);
    LayerStore::open(&store_root).unwrap().put_layer(&meta, &tar, &eng).unwrap();

    let (mut cold, mut hot) = (0.0f64, 0.0f64);
    for _ in 0..trials {
        let store = LayerStore::open(&store_root).unwrap();
        let t0 = std::time::Instant::now();
        let got = store.read_tar(&meta.id).unwrap();
        cold += t0.elapsed().as_secs_f64();
        assert_eq!(got, tar, "cold reconstruction must be bit-identical");
        let t1 = std::time::Instant::now();
        let got = store.read_tar(&meta.id).unwrap();
        hot += t1.elapsed().as_secs_f64();
        assert_eq!(got, tar, "cached read must be bit-identical");
    }
    let out = ReconstructLatency {
        tar_bytes: tar.len() as u64,
        cold_secs: cold / trials as f64,
        hot_secs: hot / trials as f64,
    };

    let mut table = Table::new(
        &format!("read_tar latency, {} KiB layer ({trials} trials)", out.tar_bytes / 1024),
        &["path", "mean"],
    );
    table.row(vec!["cold (pool reassembly)".into(), fmt_secs(out.cold_secs)]);
    table.row(vec!["hot (tar cache)".into(), fmt_secs(out.hot_secs)]);
    table.print();
    out
}

/// Write the machine-readable baseline: once into `bench_results/` and
/// once at the repository root (the trajectory file later PRs compare
/// against).
fn emit_baseline(history: &HistoryStorage, recon: &ReconstructLatency, trials: usize) {
    let doc = Json::obj(vec![
        ("bench", Json::str("dedup_store")),
        ("measured", Json::Bool(true)),
        ("revisions", Json::num(REVISIONS as f64)),
        ("asset_bytes", Json::num(ASSET_LEN as f64)),
        ("trials", Json::num(trials as f64)),
        ("logical_bytes", Json::num(history.logical_bytes as f64)),
        ("pool_bytes_single", Json::num(history.pool_bytes_single as f64)),
        ("pool_bytes_full", Json::num(history.pool_bytes_full as f64)),
        ("store_bytes_full", Json::num(history.store_bytes_full as f64)),
        ("pool_chunks_full", Json::num(history.pool_chunks_full as f64)),
        (
            "pool_growth_fraction",
            Json::num(
                (history.pool_bytes_full - history.pool_bytes_single) as f64
                    / (history.pool_bytes_single as f64).max(1.0),
            ),
        ),
        (
            "store_vs_logical_fraction",
            Json::num(history.store_bytes_full as f64 / (history.logical_bytes as f64).max(1.0)),
        ),
        (
            "reconstruct",
            Json::obj(vec![
                ("tar_bytes", Json::num(recon.tar_bytes as f64)),
                ("cold_secs", Json::num(recon.cold_secs)),
                ("hot_secs", Json::num(recon.hot_secs)),
            ]),
        ),
    ]);
    let text = doc.to_string_pretty();
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_dedup_store.json", &text).expect("write baseline");
    // Repo root (cargo bench runs from the package dir `rust/`).
    if std::fs::write("../BENCH_dedup_store.json", &text).is_ok() {
        eprintln!("wrote ../BENCH_dedup_store.json");
    }
}
