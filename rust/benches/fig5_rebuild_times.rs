//! **E1 / Fig. 5** — image rebuild time mean ± std, four scenarios,
//! Docker method vs proposed method.
//!
//! `cargo bench --bench fig5_rebuild_times` (set `LAYERJET_TRIALS` to
//! override the trial count; the paper uses 100).

mod common;

use layerjet::bench::report::{fmt_secs, Table};

fn main() {
    let n = common::trials(30);
    let experiments = common::run_all_scenarios("fig5", n, 42);

    let mut table = Table::new(
        &format!("Fig. 5 — Image rebuild time, mean ± std ({n} trials)"),
        &["scenario", "docker mean", "docker std", "proposed mean", "proposed std", "docker/proposed"],
    );
    let mut csv = String::from("scenario,method,mean_s,std_s,min_s,max_s,n\n");
    for exp in &experiments {
        let d = exp.docker_summary();
        let p = exp.proposed_summary();
        table.row(vec![
            format!("{} ({})", exp.kind.number(), exp.kind.name()),
            fmt_secs(d.mean),
            fmt_secs(d.std),
            fmt_secs(p.mean),
            fmt_secs(p.std),
            format!("{:.1}x", d.mean / p.mean.max(1e-12)),
        ]);
        for (method, s) in [("docker", d), ("proposed", p)] {
            csv.push_str(&format!(
                "{},{},{:.6},{:.6},{:.6},{:.6},{}\n",
                exp.kind.name(),
                method,
                s.mean,
                s.std,
                s.min,
                s.max,
                s.n
            ));
        }
    }
    table.print();
    common::write_csv("fig5_rebuild_times.csv", &csv);

    // Shape assertions (the paper's qualitative result).
    let mean = |i: usize| experiments[i].speedup_summary().mean;
    assert!(mean(0) > 5.0, "scenario 1 must clearly win: {}", mean(0));
    assert!(mean(1) > 20.0, "scenario 2 must win big: {}", mean(1));
    assert!(mean(2) > 2.0, "scenario 3 must win: {}", mean(2));
    assert!(
        mean(3) > 0.4 && mean(3) < 3.0,
        "scenario 4 must be a wash: {}",
        mean(3)
    );
    eprintln!("fig5 shape checks OK");
}
