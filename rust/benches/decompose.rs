//! **E8 / §III.A** — explicit vs implicit decomposition.
//!
//! "Removing an intermediate stage, decomposing implicitly is much
//! faster than explicitly, as the experiment shows below."
//!
//! `cargo bench --bench decompose`

mod common;

use layerjet::bench::report::{fmt_secs, Table};
use layerjet::bench::run_scenario_experiment;
use layerjet::builder::CostModel;
use layerjet::inject::InjectMode;
use layerjet::stats::summarize;
use layerjet::workload::ScenarioKind;

fn main() {
    let n = common::trials(20);
    let root = common::bench_root("decompose");
    let mut table = Table::new(
        &format!("§III.A — explicit vs implicit decomposition ({n} trials)"),
        &["scenario", "implicit mean", "explicit mean", "explicit/implicit"],
    );
    let mut csv = String::from("scenario,mode,mean_s,std_s,n\n");
    for kind in [ScenarioKind::PythonTiny, ScenarioKind::PythonLarge] {
        let implicit = run_scenario_experiment(
            kind,
            n,
            &root.join(format!("{}-imp", kind.name())),
            CostModel::default(),
            InjectMode::Implicit,
            7,
        )
        .expect("implicit run");
        let explicit = run_scenario_experiment(
            kind,
            n,
            &root.join(format!("{}-exp", kind.name())),
            CostModel::default(),
            InjectMode::Explicit,
            7,
        )
        .expect("explicit run");
        let si = summarize(&implicit.proposed);
        let se = summarize(&explicit.proposed);
        table.row(vec![
            kind.name().into(),
            fmt_secs(si.mean),
            fmt_secs(se.mean),
            format!("{:.1}x", se.mean / si.mean.max(1e-12)),
        ]);
        csv.push_str(&format!("{},implicit,{:.6},{:.6},{}\n", kind.name(), si.mean, si.std, si.n));
        csv.push_str(&format!("{},explicit,{:.6},{:.6},{}\n", kind.name(), se.mean, se.std, se.n));

        assert!(
            se.mean > si.mean,
            "{}: explicit ({}) must be slower than implicit ({})",
            kind.name(),
            se.mean,
            si.mean
        );
    }
    table.print();
    common::write_csv("decompose_explicit_vs_implicit.csv", &csv);
    let _ = std::fs::remove_dir_all(&root);
    eprintln!("decompose shape check OK (implicit faster, as §III.A claims)");
}
