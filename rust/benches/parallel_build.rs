//! **Parallel build engine** — 1-vs-N-thread full builds and the
//! data-parallel chunked re-hash, with a machine-readable baseline
//! (`BENCH_parallel_build.json`) so later perf PRs have a trajectory to
//! beat.
//!
//! `cargo bench --bench parallel_build` (set `LAYERJET_TRIALS` to
//! override the trial count).

mod common;

use layerjet::bench::report::{fmt_secs, Table};
use layerjet::bench::time_trials;
use layerjet::builder::{BuildOptions, CostModel};
use layerjet::daemon::Daemon;
use layerjet::hash::{ChunkDigest, ParallelEngine};
use layerjet::stats::summarize;
use layerjet::util::json::Json;
use layerjet::util::prng::Prng;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let n = common::trials(8);
    let hash = hash_sweep(n);
    let build = build_sweep(n);
    emit_baseline(n, &hash, &build);

    // Shape assertion (the acceptance bar for this PR's hot path): the
    // multi-chunk hashing benchmark must clear 1.5x at 4 threads. Only
    // meaningful on hardware that can actually run 4 threads — on
    // smaller machines the number is a hardware property, not an engine
    // regression, so report instead of panic.
    let t1 = hash[0].1;
    let t4 = hash.iter().find(|(t, _)| *t == 4).unwrap().1;
    let speedup = t1 / t4.max(1e-12);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores >= 4 {
        assert!(
            speedup >= 1.5,
            "4-thread chunk hashing speedup {speedup:.2}x < 1.5x on {cores} cores — parallel engine regressed"
        );
        eprintln!("parallel_build shape checks OK ({speedup:.2}x hashing at 4 threads)");
    } else {
        eprintln!(
            "parallel_build: only {cores} core(s) available — speedup assertion skipped \
             (measured {speedup:.2}x at 4 threads)"
        );
    }
}

/// Chunked re-hash of a 32 MiB buffer across thread counts.
/// Returns `(threads, mean seconds)` per point.
fn hash_sweep(n: usize) -> Vec<(usize, f64)> {
    let mut rng = Prng::new(0xbeef);
    let mut data = vec![0u8; 32 << 20];
    rng.fill_bytes(&mut data);

    let mut table = Table::new(
        &format!("chunked digest, 32 MiB buffer ({n} trials)"),
        &["threads", "mean", "speedup vs 1"],
    );
    let mut out = Vec::new();
    let mut base = 0.0;
    for threads in THREADS {
        let engine = ParallelEngine::new(threads);
        let t = summarize(&time_trials(1, n, |_| {
            let _ = ChunkDigest::compute(&data, &engine);
        }));
        if threads == 1 {
            base = t.mean;
        }
        table.row(vec![
            threads.to_string(),
            fmt_secs(t.mean),
            format!("{:.2}x", base / t.mean.max(1e-12)),
        ]);
        out.push((threads, t.mean));
    }
    table.print();
    out
}

/// Full no-cache builds of a project with several independent layers,
/// `jobs = 1` vs `jobs = N`. Returns `(jobs, mean seconds)` per point.
fn build_sweep(n: usize) -> Vec<(usize, f64)> {
    let root = common::bench_root("parallel-build");
    let project = root.join("project");
    std::fs::create_dir_all(&project).unwrap();
    std::fs::write(
        project.join("Dockerfile"),
        "FROM python:alpine\n\
         COPY . /app/\n\
         RUN pip install alpha beta gamma\n\
         RUN pip install delta epsilon\n\
         RUN apt update && apt install curl git -y\n\
         RUN pip install zeta\n\
         CMD [\"python\", \"app/main.py\"]\n",
    )
    .unwrap();
    std::fs::write(project.join("main.py"), "print('v0')\n").unwrap();

    let mut table = Table::new(
        &format!("full no-cache build, 7 steps ({n} trials)"),
        &["jobs", "mean", "speedup vs 1"],
    );
    let mut out = Vec::new();
    let mut base = 0.0;
    let mut image_ids = Vec::new();
    for jobs in THREADS {
        let mut daemon = Daemon::new(&root.join(format!("daemon-j{jobs}"))).unwrap();
        daemon.cost = CostModel::default();
        let opts = BuildOptions {
            no_cache: true,
            cost: CostModel::default(),
            jobs,
        };
        let mut image_id = None;
        let t = summarize(&time_trials(1, n, |_| {
            let r = daemon.build_with(&project, "pbench:latest", &opts).unwrap();
            image_id = Some(r.image_id);
        }));
        if jobs == 1 {
            base = t.mean;
        }
        image_ids.push(image_id.expect("at least one trial ran"));
        table.row(vec![
            jobs.to_string(),
            fmt_secs(t.mean),
            format!("{:.2}x", base / t.mean.max(1e-12)),
        ]);
        out.push((jobs, t.mean));
    }
    // Determinism gate: every jobs level must land on the same image.
    assert!(
        image_ids.windows(2).all(|w| w[0] == w[1]),
        "jobs levels diverged: {image_ids:?}"
    );
    table.print();
    let _ = std::fs::remove_dir_all(&root);
    out
}

/// Write the machine-readable baseline: once into `bench_results/` and
/// once at the repository root (the trajectory file later perf PRs
/// compare against).
fn emit_baseline(n: usize, hash: &[(usize, f64)], build: &[(usize, f64)]) {
    let point = |(threads, mean): &(usize, f64)| {
        Json::obj(vec![
            ("threads", Json::num(*threads as f64)),
            ("mean_s", Json::num(*mean)),
        ])
    };
    let speedup_at = |series: &[(usize, f64)], t: usize| {
        let base = series[0].1;
        series
            .iter()
            .find(|(x, _)| *x == t)
            .map(|(_, m)| base / m.max(1e-12))
            .unwrap_or(f64::NAN)
    };
    let doc = Json::obj(vec![
        ("bench", Json::str("parallel_build")),
        ("measured", Json::Bool(true)),
        ("trials", Json::num(n as f64)),
        ("hash_32mib", Json::Arr(hash.iter().map(point).collect())),
        ("build_nocache", Json::Arr(build.iter().map(point).collect())),
        ("hash_speedup_4t", Json::num(speedup_at(hash, 4))),
        ("build_speedup_4j", Json::num(speedup_at(build, 4))),
    ]);
    let text = doc.to_string_pretty();
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_parallel_build.json", &text).expect("write baseline");
    // Repo root (cargo bench runs from the package dir `rust/`).
    if std::fs::write("../BENCH_parallel_build.json", &text).is_ok() {
        eprintln!("wrote ../BENCH_parallel_build.json");
    }
    eprintln!("wrote bench_results/BENCH_parallel_build.json");
}
