//! Shared bench plumbing (no criterion in this environment; benches are
//! `harness = false` binaries).

// Each bench binary compiles its own copy of this module and uses a
// different subset of it; unused helpers are expected per target.
#![allow(dead_code)]

use layerjet::bench::{run_scenario_experiment, ScenarioExperiment};
use layerjet::builder::CostModel;
use layerjet::inject::InjectMode;
use layerjet::workload::ScenarioKind;

/// Trials per scenario: `LAYERJET_TRIALS` env or the default.
pub fn trials(default: usize) -> usize {
    std::env::var("LAYERJET_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Bench workspace root (wiped per run).
pub fn bench_root(name: &str) -> std::path::PathBuf {
    let root = std::env::temp_dir().join(format!("layerjet-bench-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    root
}

/// Run all four scenarios with the default cost model.
pub fn run_all_scenarios(name: &str, n: usize, seed: u64) -> Vec<ScenarioExperiment> {
    let root = bench_root(name);
    let mut out = Vec::new();
    for kind in ScenarioKind::ALL {
        eprint!("[{}] scenario {} ({}): {} trials ... ", name, kind.number(), kind.name(), n);
        let t0 = std::time::Instant::now();
        let exp = run_scenario_experiment(
            kind,
            n,
            &root.join(kind.name()),
            CostModel::default(),
            InjectMode::Implicit,
            seed,
        )
        .expect("scenario experiment failed");
        eprintln!("{:.1}s", t0.elapsed().as_secs_f64());
        out.push(exp);
    }
    let _ = std::fs::remove_dir_all(&root);
    out
}

/// Write a CSV into bench_results/.
pub fn write_csv(file: &str, contents: &str) {
    std::fs::create_dir_all("bench_results").ok();
    let path = format!("bench_results/{file}");
    std::fs::write(&path, contents).expect("write csv");
    eprintln!("wrote {path}");
}
