//! **E3 / Table II** — one-sided Z hypothesis tests on the speedup means.
//!
//! The paper tests H₀: µ ≤ H₀ with H₀ = {100, 105000, 20, 0.7} at
//! α = 0.001 and rejects all four. Our workloads are scaled ~100× down
//! (DESIGN.md §4), which caps the *absolute* ratio scenario 2 can reach
//! (its 105000× came from minutes-long conda rebuilds vs ms injections),
//! so the bench reports the test against both the **paper's H₀** (honest
//! reproduction at scale) and the **scale-adjusted H₀** (scenario 2's
//! divided by the workload scale factor; the other scenarios' H₀ are
//! overhead-ratio-bound, not size-bound, and stay as published).
//!
//! `cargo bench --bench table2_hypothesis`

mod common;

use layerjet::bench::report::{fmt_p, fmt_speedup, Table};
use layerjet::stats::z_test;
use layerjet::workload::ScenarioKind;

/// (kind, paper H0, scale-adjusted H0).
///
/// Scale adjustment rationale (EXPERIMENTS.md §Table II): scenarios 1-3's
/// ratios are bounded by (docker per-build overhead)/(injection floor);
/// our overheads are scaled ~100× below dockerd's (CostModel docs) while
/// the injection floor (file IO + metadata) shrinks less, compressing the
/// achievable ratio roughly 2-4×. Scenario 2 is additionally bounded by
/// workload size while the injection floor stays ~fixed. Scenario 4's H0 is a *lower* bound on a ~1×
/// result and needs no scaling.
const H0: [(ScenarioKind, f64, f64); 4] = [
    (ScenarioKind::PythonTiny, 100.0, 25.0),
    (ScenarioKind::PythonLarge, 105_000.0, 75.0),
    (ScenarioKind::JavaTiny, 20.0, 5.0),
    (ScenarioKind::JavaLarge, 0.7, 0.7),
];

fn main() {
    let n = common::trials(30);
    let experiments = common::run_all_scenarios("table2", n, 44);

    let mut table = Table::new(
        &format!("Table II — Hypothesis tests (alpha = 0.001, n = {n})"),
        &["scenario", "mean speedup", "paper H0", "P (paper)", "reject?", "scaled H0", "P (scaled)", "reject?"],
    );
    let mut csv = String::from("scenario,mean,h0_paper,p_paper,reject_paper,h0_scaled,p_scaled,reject_scaled\n");
    let mut scaled_rejects = Vec::new();
    for exp in &experiments {
        let (_, h0_paper, h0_scaled) = H0.iter().find(|(k, _, _)| *k == exp.kind).unwrap();
        let s = exp.speedup_summary();
        let tp = z_test(&s, *h0_paper, 0.001);
        let ts = z_test(&s, *h0_scaled, 0.001);
        scaled_rejects.push((exp.kind, ts.reject));
        table.row(vec![
            format!("{} ({})", exp.kind.number(), exp.kind.name()),
            fmt_speedup(s.mean),
            format!("{h0_paper}"),
            fmt_p(tp.p),
            yesno(tp.reject),
            format!("{h0_scaled}"),
            fmt_p(ts.p),
            yesno(ts.reject),
        ]);
        csv.push_str(&format!(
            "{},{:.4},{},{:.6e},{},{},{:.6e},{}\n",
            exp.kind.name(),
            s.mean,
            h0_paper,
            tp.p,
            tp.reject,
            h0_scaled,
            ts.p,
            ts.reject
        ));
    }
    table.print();
    common::write_csv("table2_hypothesis.csv", &csv);

    // The paper's conclusion at our scale: scenarios 1-3 reject their
    // (scaled) H0; scenario 4 rejects H0=0.7 as well ("no significant
    // improvement, but not worse than 0.7x"). The assertion is only
    // enforced at a statistically meaningful trial count — the official
    // record is the 100-trial paper_scenarios run.
    if n >= 30 {
        for (kind, reject) in &scaled_rejects {
            assert!(
                *reject,
                "scenario {} failed to reject its scale-adjusted H0",
                kind.number()
            );
        }
        eprintln!("table2 scaled-H0 rejections OK");
    } else {
        eprintln!("table2: n = {n} < 30 — rejection assertions skipped (informational run)");
    }
}

fn yesno(b: bool) -> String {
    if b { "yes".into() } else { "no".into() }
}
