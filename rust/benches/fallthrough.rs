//! **E5 / Fig. 2 + §II.C** — layer fall-through.
//!
//! Part A reproduces Fig. 2: on the python-large project, a change in
//! step 2 (COPY) forces steps 4+ (apt, conda) to rebuild even though
//! they do not depend on the edit; the per-step breakdown shows where
//! the time goes and that the rebuilt RUN layers are byte-identical to
//! the cached ones (pure waste).
//!
//! Part B sweeps fall-through *depth*: k RUN layers stacked behind the
//! COPY; Docker's rebuild grows with k, injection stays flat.
//!
//! `cargo bench --bench fallthrough`

mod common;

use layerjet::bench::report::{fmt_secs, Table};
use layerjet::builder::CostModel;
use layerjet::daemon::Daemon;
use layerjet::workload::{Scenario, ScenarioKind};

fn main() {
    part_a_fig2();
    part_b_depth_sweep();
}

fn part_a_fig2() {
    let root = common::bench_root("fallthrough-a");
    let mut daemon = Daemon::new(&root.join("daemon")).unwrap();
    daemon.cost = CostModel::default();
    let mut scenario = Scenario::generate(ScenarioKind::PythonLarge, &root.join("p"), 5).unwrap();
    let first = daemon.build(&scenario.dir, "large:latest").unwrap();

    scenario.revise().unwrap();
    let rebuild = daemon.build(&scenario.dir, "large:latest").unwrap();

    let mut table = Table::new(
        "Fig. 2 — change at step 2 falls through to steps 3..n",
        &["step", "instruction", "cache", "reason", "time", "identical to v0?"],
    );
    for (i, step) in rebuild.steps.iter().enumerate() {
        let identical = first.steps[i].checksum == step.checksum;
        table.row(vec![
            format!("{}/{}", step.step, rebuild.steps.len()),
            step.instruction.chars().take(44).collect(),
            if step.cached { "hit".into() } else { "MISS".into() },
            step.miss_reason
                .as_ref()
                .map(|r| r.to_string())
                .unwrap_or_default(),
            fmt_secs(step.duration.as_secs_f64()),
            if identical { "yes".into() } else { "no".into() },
        ]);
    }
    table.print();

    // The apt/conda layers fell through AND produced identical bytes.
    let apt = rebuild.steps.iter().find(|s| s.instruction.contains("apt update")).unwrap();
    let conda = rebuild.steps.iter().find(|s| s.instruction.contains("conda env update")).unwrap();
    assert!(!apt.cached && !conda.cached, "fall-through must rebuild RUN layers");
    assert_eq!(
        apt.checksum,
        first.steps.iter().find(|s| s.instruction.contains("apt update")).unwrap().checksum,
        "rebuilt apt layer is byte-identical — wasted work"
    );
    let wasted: f64 = [apt, conda].iter().map(|s| s.duration.as_secs_f64()).sum();
    eprintln!(
        "fall-through wasted {} rebuilding identical layers ({}% of the rebuild)\n",
        fmt_secs(wasted),
        (100.0 * wasted / rebuild.duration.as_secs_f64()) as u32
    );
    let _ = std::fs::remove_dir_all(&root);
}

fn part_b_depth_sweep() {
    let n = common::trials(5);
    let root = common::bench_root("fallthrough-b");
    let mut table = Table::new(
        &format!("§II.C — fall-through depth sweep ({n} trials/point)"),
        &["RUN layers behind COPY", "docker mean", "inject mean", "speedup"],
    );
    let mut csv = String::from("depth,docker_mean_s,inject_mean_s\n");
    let mut prev_docker = 0.0;
    for depth in [0usize, 1, 2, 4, 8] {
        let case = root.join(format!("d{depth}"));
        let project = case.join("project");
        std::fs::create_dir_all(&project).unwrap();
        let mut df = String::from("FROM python:alpine\nCOPY . /app/\n");
        for i in 0..depth {
            // Distinct pip packages per layer: each fall-through layer
            // redownloads and regenerates its content.
            df.push_str(&format!("RUN pip install pkg{i}a pkg{i}b\n"));
        }
        df.push_str("CMD [\"python\", \"app/main.py\"]\n");
        std::fs::write(project.join("Dockerfile"), df).unwrap();
        std::fs::write(project.join("main.py"), "print('v0')\n").unwrap();

        let mut daemon_d = Daemon::new(&case.join("docker")).unwrap();
        let mut daemon_i = Daemon::new(&case.join("inject")).unwrap();
        daemon_d.cost = CostModel::default();
        daemon_i.cost = CostModel::default();
        daemon_d.build(&project, "depth:latest").unwrap();
        daemon_i.build(&project, "depth:latest").unwrap();

        let mut docker = Vec::new();
        let mut inject = Vec::new();
        for t in 0..n {
            let mut main = std::fs::read_to_string(project.join("main.py")).unwrap();
            main.push_str(&format!("print('edit {t}')\n"));
            std::fs::write(project.join("main.py"), main).unwrap();
            let t0 = std::time::Instant::now();
            daemon_d.build(&project, "depth:latest").unwrap();
            docker.push(t0.elapsed().as_secs_f64());
            let t0 = std::time::Instant::now();
            daemon_i.inject(&project, "depth:latest", "depth:latest").unwrap();
            inject.push(t0.elapsed().as_secs_f64());
        }
        let d = layerjet::stats::summarize(&docker);
        let p = layerjet::stats::summarize(&inject);
        table.row(vec![
            depth.to_string(),
            fmt_secs(d.mean),
            fmt_secs(p.mean),
            format!("{:.1}x", d.mean / p.mean.max(1e-12)),
        ]);
        csv.push_str(&format!("{},{:.6},{:.6}\n", depth, d.mean, p.mean));
        if depth >= 2 {
            assert!(
                d.mean > prev_docker,
                "docker rebuild must grow with fall-through depth"
            );
        }
        prev_docker = d.mean;
    }
    table.print();
    common::write_csv("fallthrough_depth.csv", &csv);
    let _ = std::fs::remove_dir_all(&root);
    eprintln!("fallthrough depth sweep OK (docker grows with depth, inject flat)");
}
