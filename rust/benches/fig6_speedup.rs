//! **E2 / Fig. 6** — how many times faster the proposed method is than
//! the Docker method, per scenario (paired per trial).
//!
//! `cargo bench --bench fig6_speedup`

mod common;

use layerjet::bench::report::{fmt_speedup, Table};
use layerjet::stats::percentile;

fn main() {
    let n = common::trials(30);
    let experiments = common::run_all_scenarios("fig6", n, 43);

    let mut table = Table::new(
        &format!("Fig. 6 — Proposed method, times faster than Docker ({n} trials)"),
        &["scenario", "mean", "std", "p10", "median", "p90", "max"],
    );
    let mut csv = String::from("scenario,trial,speedup\n");
    for exp in &experiments {
        let s = exp.speedup_summary();
        table.row(vec![
            format!("{} ({})", exp.kind.number(), exp.kind.name()),
            fmt_speedup(s.mean),
            fmt_speedup(s.std),
            fmt_speedup(percentile(&exp.speedup, 10.0)),
            fmt_speedup(percentile(&exp.speedup, 50.0)),
            fmt_speedup(percentile(&exp.speedup, 90.0)),
            fmt_speedup(s.max),
        ]);
        for (i, x) in exp.speedup.iter().enumerate() {
            csv.push_str(&format!("{},{},{:.4}\n", exp.kind.name(), i, x));
        }
    }
    table.print();
    common::write_csv("fig6_speedup.csv", &csv);

    // Ordering shape: interpreted scenarios dominate; compiled-complex ~1x.
    let mean = |i: usize| experiments[i].speedup_summary().mean;
    assert!(
        mean(1) > mean(2) && mean(2) > mean(3),
        "expected s2 > s3 > s4 ordering: {} {} {}",
        mean(1),
        mean(2),
        mean(3)
    );
    eprintln!("fig6 shape checks OK");
}
