//! **Sharded pull traffic** — what the origin registry actually serves
//! when a fleet of edge pullers goes through a shared persistent pull
//! cache, plus the migration cost of growing the shard ring. Emits a
//! machine-readable baseline (`BENCH_sharded_pull.json`).
//!
//! Two experiments:
//! * **origin offload** — waves of concurrent pulls into fresh stores,
//!   all reading through one [`PullCache`]: the origin should serve
//!   roughly ONE copy of the image no matter how many pullers arrive
//!   (the headline: overall bytes-from-origin < 10% of bytes pulled,
//!   and a fully-warm wave < 10% on its own);
//! * **reshard cost** — growing the ring 2 → 3 must migrate a strict
//!   minority of chunks (consistent hashing moves ~1/3 of the
//!   keyspace, never a full reshuffle).
//!
//! `cargo bench --bench sharded_pull` (set `LAYERJET_TRIALS` to
//! override the wave count).

mod common;

use layerjet::bench::report::{fmt_secs, Table};
use layerjet::builder::CostModel;
use layerjet::daemon::Daemon;
use layerjet::registry::{PullCache, PullOptions, RemoteRegistry};
use layerjet::util::json::Json;
use layerjet::util::prng::Prng;
use std::path::Path;
use std::sync::Mutex;

/// Concurrent pullers per wave.
const WAVE_WIDTH: usize = 8;

fn main() {
    let waves = common::trials(8).max(2);
    let root = common::bench_root("sharded-pull");
    let offload = origin_offload_sweep(&root, waves);
    let reshard = reshard_sweep(&root);
    emit_baseline(waves, &offload, &reshard);

    // Shape assertions (the cache tier's acceptance bars): once the
    // cache is warm the origin serves a sliver of what the fleet pulls.
    // Wave 0 is excluded — its concurrent cold pullers legitimately
    // race to the origin (write-through lands only after each layer
    // verifies). Protocol properties, not timing — safe on any machine.
    assert!(
        offload.warm_origin_fraction < 0.10,
        "warm waves pulled {:.1}% from origin — the cache tier regressed",
        offload.warm_origin_fraction * 100.0
    );
    assert!(
        offload.warm_wave_origin_fraction < 0.10,
        "the last wave still pulled {:.1}% from origin — read-through regressed",
        offload.warm_wave_origin_fraction * 100.0
    );
    assert!(
        reshard.migrated_fraction < 0.5,
        "2→3 reshard migrated {:.1}% of chunks — consistent hashing regressed",
        reshard.migrated_fraction * 100.0
    );
    eprintln!(
        "sharded_pull shape checks OK ({:.2}% of warm-wave bytes from origin over {} pulls; \
         2→3 reshard moved {:.1}% of chunks)",
        offload.warm_origin_fraction * 100.0,
        offload.pulls,
        reshard.migrated_fraction * 100.0
    );
    let _ = std::fs::remove_dir_all(&root);
}

struct OriginOffload {
    pulls: usize,
    transferred_bytes: u64,
    origin_bytes: u64,
    /// Origin fraction over every wave, wave 0's cold stampede included.
    overall_origin_fraction: f64,
    /// Origin fraction over waves 1.. (the steady state the headline
    /// assertion holds to).
    warm_origin_fraction: f64,
    cold_wave_origin_fraction: f64,
    /// Origin fraction of the final wave alone.
    warm_wave_origin_fraction: f64,
}

struct ReshardCost {
    chunks_scanned: usize,
    chunks_migrated: usize,
    migrated_fraction: f64,
    balance_factor: f64,
}

/// A project whose COPY layer is dominated by a deterministic ~2 MiB
/// asset, so each pull moves enough chunks to make fractions stable.
fn write_project(dir: &Path) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(
        dir.join("Dockerfile"),
        "FROM python:alpine\nCOPY . /srv/\nCMD [\"python\", \"zz_main.py\"]\n",
    )
    .unwrap();
    let mut asset = vec![0u8; 2 << 20];
    Prng::new(0x0ff10ad).fill_bytes(&mut asset);
    std::fs::write(dir.join("aa_assets.bin"), &asset).unwrap();
    std::fs::write(dir.join("zz_main.py"), "print('v1')\n").unwrap();
}

/// Waves of `WAVE_WIDTH` concurrent pulls into fresh stores, all
/// sharing one persistent pull cache against a 3-shard remote.
fn origin_offload_sweep(root: &Path, waves: usize) -> OriginOffload {
    let proj = root.join("offload-proj");
    write_project(&proj);
    let mut dev = Daemon::new(&root.join("offload-daemon")).unwrap();
    dev.cost = CostModel::instant();
    dev.build(&proj, "obench:v1").unwrap();
    let remote = RemoteRegistry::open(&root.join("offload-remote")).unwrap();
    dev.push("obench:v1", &remote).unwrap();
    remote.shard_to(3).unwrap();
    let cache = PullCache::open_default(&root.join("offload-edge-cache")).unwrap();

    let mut table = Table::new(
        &format!("{WAVE_WIDTH} concurrent pulls per wave through one pull cache ({waves} waves)"),
        &["wave", "origin bytes", "cache bytes", "origin %", "wall"],
    );
    let mut out = OriginOffload {
        pulls: 0,
        transferred_bytes: 0,
        origin_bytes: 0,
        overall_origin_fraction: f64::NAN,
        warm_origin_fraction: f64::NAN,
        cold_wave_origin_fraction: f64::NAN,
        warm_wave_origin_fraction: f64::NAN,
    };
    let (mut warm_transferred, mut warm_origin) = (0u64, 0u64);
    for wave in 0..waves {
        let reports: Mutex<Vec<(u64, u64)>> = Mutex::new(Vec::new());
        let t0 = std::time::Instant::now();
        std::thread::scope(|scope| {
            for p in 0..WAVE_WIDTH {
                let store = root.join(format!("offload-store-w{wave}-p{p}"));
                let remote = &remote;
                let cache = cache.clone();
                let reports = &reports;
                scope.spawn(move || {
                    let puller = Daemon::new(&store).unwrap();
                    let r = puller
                        .pull_with(
                            "obench:v1",
                            remote,
                            &PullOptions { jobs: 1, pull_cache: Some(cache), ..Default::default() },
                        )
                        .unwrap();
                    reports.lock().unwrap().push((r.bytes_from_origin, r.bytes_from_cache));
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let (origin, cached) = reports
            .lock()
            .unwrap()
            .iter()
            .fold((0u64, 0u64), |(o, c), &(ro, rc)| (o + ro, c + rc));
        let transferred = origin + cached;
        let fraction = origin as f64 / (transferred as f64).max(1.0);
        if wave == 0 {
            out.cold_wave_origin_fraction = fraction;
        } else {
            warm_transferred += transferred;
            warm_origin += origin;
        }
        out.warm_wave_origin_fraction = fraction;
        out.pulls += WAVE_WIDTH;
        out.transferred_bytes += transferred;
        out.origin_bytes += origin;
        table.row(vec![
            wave.to_string(),
            origin.to_string(),
            cached.to_string(),
            format!("{:.1}%", fraction * 100.0),
            fmt_secs(wall),
        ]);
        // Fresh stores per wave; wipe them so the bench's disk footprint
        // stays bounded by one wave, not waves × fleet.
        for p in 0..WAVE_WIDTH {
            let _ = std::fs::remove_dir_all(root.join(format!("offload-store-w{wave}-p{p}")));
        }
    }
    out.overall_origin_fraction = out.origin_bytes as f64 / (out.transferred_bytes as f64).max(1.0);
    out.warm_origin_fraction = warm_origin as f64 / (warm_transferred as f64).max(1.0);
    table.print();
    out
}

/// Grow a loaded 2-shard pool to 3 and measure how much actually moved.
fn reshard_sweep(root: &Path) -> ReshardCost {
    let proj = root.join("reshard-proj");
    write_project(&proj);
    let mut dev = Daemon::new(&root.join("reshard-daemon")).unwrap();
    dev.cost = CostModel::instant();
    dev.build(&proj, "rbench:v1").unwrap();
    let remote = RemoteRegistry::open(&root.join("reshard-remote")).unwrap();
    dev.push("rbench:v1", &remote).unwrap();
    remote.shard_to(2).unwrap();

    let t0 = std::time::Instant::now();
    let report = remote.shard_to(3).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let (_, balance) = remote.shard_stats().unwrap();
    let out = ReshardCost {
        chunks_scanned: report.chunks_scanned,
        chunks_migrated: report.chunks_migrated,
        migrated_fraction: report.chunks_migrated as f64 / (report.chunks_scanned as f64).max(1.0),
        balance_factor: balance,
    };

    let mut table = Table::new(
        "reshard 2 → 3 backends",
        &["chunks", "migrated", "fraction", "balance", "wall"],
    );
    table.row(vec![
        out.chunks_scanned.to_string(),
        out.chunks_migrated.to_string(),
        format!("{:.1}%", out.migrated_fraction * 100.0),
        format!("{:.2}", out.balance_factor),
        fmt_secs(wall),
    ]);
    table.print();
    out
}

/// Write the machine-readable baseline: once into `bench_results/` and
/// once at the repository root (the trajectory file later PRs compare
/// against).
fn emit_baseline(waves: usize, offload: &OriginOffload, reshard: &ReshardCost) {
    let doc = Json::obj(vec![
        ("bench", Json::str("sharded_pull")),
        ("measured", Json::Bool(true)),
        ("waves", Json::num(waves as f64)),
        ("wave_width", Json::num(WAVE_WIDTH as f64)),
        ("pulls", Json::num(offload.pulls as f64)),
        ("transferred_bytes", Json::num(offload.transferred_bytes as f64)),
        ("origin_bytes", Json::num(offload.origin_bytes as f64)),
        ("overall_origin_fraction", Json::num(offload.overall_origin_fraction)),
        ("warm_origin_fraction", Json::num(offload.warm_origin_fraction)),
        ("cold_wave_origin_fraction", Json::num(offload.cold_wave_origin_fraction)),
        ("warm_wave_origin_fraction", Json::num(offload.warm_wave_origin_fraction)),
        (
            "reshard_2_to_3",
            Json::obj(vec![
                ("chunks_scanned", Json::num(reshard.chunks_scanned as f64)),
                ("chunks_migrated", Json::num(reshard.chunks_migrated as f64)),
                ("migrated_fraction", Json::num(reshard.migrated_fraction)),
                ("balance_factor", Json::num(reshard.balance_factor)),
            ]),
        ),
    ]);
    let text = doc.to_string_pretty();
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_sharded_pull.json", &text).expect("write baseline");
    // Repo root (cargo bench runs from the package dir `rust/`).
    if std::fs::write("../BENCH_sharded_pull.json", &text).is_ok() {
        eprintln!("wrote ../BENCH_sharded_pull.json");
    }
    eprintln!("wrote bench_results/BENCH_sharded_pull.json");
}
