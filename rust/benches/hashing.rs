//! **E10 / §Perf** — hashing ablation: the checksum machinery that both
//! Docker's integrity test and the §III.B bypass depend on.
//!
//! * native streaming SHA-256 vs the batched AOT/PJRT engine, across
//!   buffer sizes (the L1/L3 perf story);
//! * full re-hash vs incremental chunk-digest update for a 1-chunk edit
//!   (the O(n) → O(change) mechanism inside the injector).
//!
//! `cargo bench --bench hashing`

mod common;

use layerjet::bench::report::{fmt_secs, Table};
use layerjet::bench::time_trials;
use layerjet::hash::{ChunkDigest, Digest, HashEngine, NativeEngine, CHUNK_SIZE};
use layerjet::runtime::PjrtEngine;
use layerjet::stats::summarize;
use layerjet::util::prng::Prng;

fn main() {
    let n = common::trials(10);
    let pjrt = PjrtEngine::load_default();
    if pjrt.is_err() {
        eprintln!("NOTE: PJRT artifacts missing (run `make artifacts`); engine rows limited to native");
    }

    // --- engine comparison ---------------------------------------------------
    let mut table = Table::new(
        &format!("hash engines: chunked digest over a buffer ({n} trials)"),
        &["buffer", "native", "pjrt-xla", "native/pjrt", "sequential sha256"],
    );
    let mut csv = String::from("buffer_bytes,native_s,pjrt_s,sequential_s\n");
    for mib in [0.25f64, 1.0, 4.0, 16.0] {
        let bytes = (mib * 1048576.0) as usize;
        let mut rng = Prng::new(bytes as u64);
        let mut data = vec![0u8; bytes];
        rng.fill_bytes(&mut data);

        let native = NativeEngine::new();
        let tn = summarize(&time_trials(1, n, |_| {
            let _ = ChunkDigest::compute(&data, &native);
        }));
        let tp = pjrt.as_ref().ok().map(|engine| {
            summarize(&time_trials(1, n, |_| {
                let _ = ChunkDigest::compute(&data, engine);
            }))
        });
        let ts = summarize(&time_trials(1, n, |_| {
            let _ = Digest::of(&data);
        }));
        table.row(vec![
            format!("{mib} MiB"),
            fmt_secs(tn.mean),
            tp.as_ref().map(|t| fmt_secs(t.mean)).unwrap_or_else(|| "-".into()),
            tp.as_ref()
                .map(|t| format!("{:.2}x", tn.mean / t.mean.max(1e-12)))
                .unwrap_or_else(|| "-".into()),
            fmt_secs(ts.mean),
        ]);
        csv.push_str(&format!(
            "{},{:.6},{:.6},{:.6}\n",
            bytes,
            tn.mean,
            tp.as_ref().map(|t| t.mean).unwrap_or(f64::NAN),
            ts.mean
        ));
    }
    table.print();
    common::write_csv("hashing_engines.csv", &csv);

    // --- incremental vs full rehash -------------------------------------------
    let mut table = Table::new(
        &format!("incremental chunk-digest update vs full rehash, 1-chunk edit ({n} trials)"),
        &["buffer", "full rehash", "incremental", "speedup", "chunks rehashed"],
    );
    let mut csv = String::from("buffer_bytes,full_s,incremental_s,chunks_rehashed,chunks_total\n");
    let native = NativeEngine::new();
    for mib in [1.0f64, 4.0, 16.0, 64.0] {
        let bytes = (mib * 1048576.0) as usize;
        let mut rng = Prng::new(7 + bytes as u64);
        let mut data = vec![0u8; bytes];
        rng.fill_bytes(&mut data);
        let cd = ChunkDigest::compute(&data, &native);

        // Edit one byte in the middle (stays within one chunk).
        let at = (bytes / 2 / CHUNK_SIZE) * CHUNK_SIZE + 17;
        data[at] ^= 0x55;
        let edit = vec![(at as u64)..(at as u64 + 1)];

        let tf = summarize(&time_trials(1, n, |_| {
            let _ = ChunkDigest::compute(&data, &native);
        }));
        let mut rehashed = 0;
        let ti = summarize(&time_trials(1, n, |_| {
            let (_, r) = cd.update(&data, &edit, &native);
            rehashed = r;
        }));
        table.row(vec![
            format!("{mib} MiB"),
            fmt_secs(tf.mean),
            fmt_secs(ti.mean),
            format!("{:.0}x", tf.mean / ti.mean.max(1e-12)),
            format!("{}/{}", rehashed, cd.chunks.len()),
        ]);
        csv.push_str(&format!(
            "{},{:.6},{:.6},{},{}\n",
            bytes,
            tf.mean,
            ti.mean,
            rehashed,
            cd.chunks.len()
        ));
        assert_eq!(rehashed, 1, "a 1-byte edit must rehash exactly 1 chunk");
        assert!(
            tf.mean / ti.mean > 10.0,
            "incremental must be >>1 order faster at {mib} MiB"
        );
    }
    table.print();
    common::write_csv("hashing_incremental.csv", &csv);
    eprintln!("hashing ablation OK");
}
