//! **Degraded pull overhead** — what replica failover costs a puller
//! when one backend of an R=2 pool is dead, and what the anti-entropy
//! repair pass pays to converge afterwards. Emits a machine-readable
//! baseline (`BENCH_degraded_pull.json`).
//!
//! Three experiments against a 2-shard, 2-replica pool:
//! * **healthy pulls** — fresh stores pull with every backend alive
//!   (the control: zero failover reads);
//! * **degraded pulls** — the same pulls with one backend taken down
//!   via the `registry.backend.read` fault site: every pull must still
//!   verify, report its failover reads, and stay within a bounded
//!   wall-clock multiple of the healthy control (failover is one local
//!   miss plus breaker bookkeeping per chunk, not a retry storm);
//! * **repair cost** — wipe one backend's copies behind the pool's
//!   back and measure the anti-entropy pass restoring full
//!   replication.
//!
//! `cargo bench --bench degraded_pull` (set `LAYERJET_TRIALS` to
//! override the pull count per sweep).

mod common;

use layerjet::bench::report::{fmt_secs, Table};
use layerjet::builder::CostModel;
use layerjet::daemon::Daemon;
use layerjet::fault::{self, FaultMode, FaultPlan};
use layerjet::registry::{PullOptions, RemoteRegistry};
use layerjet::util::json::Json;
use layerjet::util::prng::Prng;
use std::path::Path;

/// A degraded pull may cost at most this multiple of a healthy one.
/// Failover adds a failed existence probe and breaker bookkeeping per
/// chunk homed on the dead backend — cheap, but the bound stays
/// generous so the assertion holds on noisy shared runners.
const MAX_OVERHEAD_RATIO: f64 = 5.0;

fn main() {
    let trials = common::trials(8).max(3);
    let root = common::bench_root("degraded-pull");

    // A ~2 MiB deterministic asset: enough chunks that both shards home
    // a healthy share of them.
    let proj = root.join("proj");
    std::fs::create_dir_all(&proj).unwrap();
    std::fs::write(
        proj.join("Dockerfile"),
        "FROM python:alpine\nCOPY . /srv/\nCMD [\"python\", \"zz_main.py\"]\n",
    )
    .unwrap();
    let mut asset = vec![0u8; 2 << 20];
    Prng::new(0x0ff10ad).fill_bytes(&mut asset);
    std::fs::write(proj.join("aa_assets.bin"), &asset).unwrap();
    std::fs::write(proj.join("zz_main.py"), "print('v1')\n").unwrap();

    let mut dev = Daemon::new(&root.join("dev")).unwrap();
    dev.cost = CostModel::instant();
    dev.build(&proj, "dbench:v1").unwrap();
    let remote = RemoteRegistry::open(&root.join("remote")).unwrap();
    dev.push("dbench:v1", &remote).unwrap();
    remote.shard_to_with(2, 2).unwrap();
    let occ = remote.occupancy().unwrap();
    assert_eq!(
        occ.replica_chunks,
        occ.unique_chunks * 2,
        "setup must leave a fully replicated R=2 pool: {occ:?}"
    );

    let healthy = pull_sweep(&root, &remote, trials, "healthy", None);
    let shard1 = root.join("remote").join("shard-1");
    let degraded = pull_sweep(&root, &remote, trials, "degraded", Some(&shard1));
    let repair = repair_sweep(&remote, &shard1);

    let overhead = degraded.median_secs / healthy.median_secs.max(1e-9);
    let mut table = Table::new(
        &format!("degraded pull overhead ({trials} pulls per sweep)"),
        &["sweep", "median wall", "failover reads/pull", "chunks/pull"],
    );
    for s in [&healthy, &degraded] {
        table.row(vec![
            s.label.to_string(),
            fmt_secs(s.median_secs),
            format!("{:.1}", s.failover_reads as f64 / trials as f64),
            format!("{:.1}", s.chunks_fetched as f64 / trials as f64),
        ]);
    }
    table.print();

    emit_baseline(trials, &healthy, &degraded, overhead, &repair);

    // Shape assertions. The routing facts are protocol properties; the
    // overhead ratio is the one timing bar, held generous on purpose.
    assert_eq!(
        healthy.failover_reads, 0,
        "healthy pulls must never fail over"
    );
    assert!(
        degraded.failover_reads > 0,
        "degraded pulls must report failover reads"
    );
    assert!(
        overhead < MAX_OVERHEAD_RATIO,
        "degraded pulls cost {overhead:.2}x healthy — failover regressed \
         (bound {MAX_OVERHEAD_RATIO}x)"
    );
    assert!(repair.converged, "repair must converge the wiped backend");
    eprintln!(
        "degraded_pull shape checks OK ({:.2}x overhead over {} pulls; repair restored \
         {} copies in {})",
        overhead,
        trials,
        repair.chunks_repaired,
        fmt_secs(repair.wall_secs),
    );
    let _ = std::fs::remove_dir_all(&root);
}

struct PullSweep {
    label: &'static str,
    median_secs: f64,
    failover_reads: u64,
    chunks_fetched: usize,
}

struct RepairCost {
    copies_wiped: usize,
    chunks_repaired: usize,
    bytes_repaired: u64,
    wall_secs: f64,
    converged: bool,
}

/// `trials` fresh-store pulls; `dead_backend` takes that backend down
/// for reads (scoped `Unavailable`) for the whole sweep.
fn pull_sweep(
    root: &Path,
    remote: &RemoteRegistry,
    trials: usize,
    label: &'static str,
    dead_backend: Option<&Path>,
) -> PullSweep {
    let guard = dead_backend.map(|dir| {
        fault::install(
            FaultPlan::fail_at("registry.backend.read", 0, FaultMode::Unavailable(u32::MAX))
                .scoped(dir),
        )
    });
    let mut walls = Vec::with_capacity(trials);
    let mut out = PullSweep { label, median_secs: f64::NAN, failover_reads: 0, chunks_fetched: 0 };
    for t in 0..trials {
        let store = root.join(format!("{label}-store-{t}"));
        let puller = Daemon::new(&store).unwrap();
        let t0 = std::time::Instant::now();
        let r = puller
            .pull_with("dbench:v1", remote, &PullOptions { jobs: 2, ..Default::default() })
            .unwrap();
        walls.push(t0.elapsed().as_secs_f64());
        assert!(puller.verify_image("dbench:v1").unwrap(), "{label} pull {t} must verify");
        out.failover_reads += r.failover_reads;
        out.chunks_fetched += r.chunks_fetched;
        let _ = std::fs::remove_dir_all(&store);
    }
    drop(guard);
    walls.sort_by(|a, b| a.total_cmp(b));
    out.median_secs = walls[walls.len() / 2];
    out
}

/// Wipe every chunk copy off one backend (no markers — the loss is
/// silent) and measure the anti-entropy pass restoring them.
fn repair_sweep(remote: &RemoteRegistry, backend_dir: &Path) -> RepairCost {
    let chunks = backend_dir.join("chunks");
    let mut wiped = 0usize;
    for e in std::fs::read_dir(&chunks).unwrap() {
        let p = e.unwrap().path();
        if p.is_file() {
            std::fs::remove_file(&p).unwrap();
            wiped += 1;
        }
    }
    assert!(wiped > 0, "the backend must have held copies to wipe");

    let t0 = std::time::Instant::now();
    let report = remote.repair().unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let out = RepairCost {
        copies_wiped: wiped,
        chunks_repaired: report.chunks_repaired,
        bytes_repaired: report.bytes_repaired,
        wall_secs: wall,
        converged: report.is_converged(),
    };

    let mut table =
        Table::new("anti-entropy repair of a wiped backend", &["wiped", "repaired", "bytes", "wall"]);
    table.row(vec![
        out.copies_wiped.to_string(),
        out.chunks_repaired.to_string(),
        out.bytes_repaired.to_string(),
        fmt_secs(out.wall_secs),
    ]);
    table.print();
    out
}

/// Write the machine-readable baseline: once into `bench_results/` and
/// once at the repository root (the trajectory file later PRs compare
/// against).
fn emit_baseline(
    trials: usize,
    healthy: &PullSweep,
    degraded: &PullSweep,
    overhead: f64,
    repair: &RepairCost,
) {
    let doc = Json::obj(vec![
        ("bench", Json::str("degraded_pull")),
        ("measured", Json::Bool(true)),
        ("trials", Json::num(trials as f64)),
        ("healthy_median_secs", Json::num(healthy.median_secs)),
        ("degraded_median_secs", Json::num(degraded.median_secs)),
        ("overhead_ratio", Json::num(overhead)),
        ("max_overhead_ratio", Json::num(MAX_OVERHEAD_RATIO)),
        ("failover_reads", Json::num(degraded.failover_reads as f64)),
        ("chunks_fetched", Json::num(degraded.chunks_fetched as f64)),
        (
            "repair",
            Json::obj(vec![
                ("copies_wiped", Json::num(repair.copies_wiped as f64)),
                ("chunks_repaired", Json::num(repair.chunks_repaired as f64)),
                ("bytes_repaired", Json::num(repair.bytes_repaired as f64)),
                ("wall_secs", Json::num(repair.wall_secs)),
            ]),
        ),
    ]);
    let text = doc.to_string_pretty();
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_degraded_pull.json", &text).expect("write baseline");
    // Repo root (cargo bench runs from the package dir `rust/`).
    if std::fs::write("../BENCH_degraded_pull.json", &text).is_ok() {
        eprintln!("wrote ../BENCH_degraded_pull.json");
    }
    eprintln!("wrote bench_results/BENCH_degraded_pull.json");
}
