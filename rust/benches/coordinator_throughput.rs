//! **Coordinator throughput** — per-request vs step-level fleet
//! scheduling over k queued requests, with a machine-readable baseline
//! (`BENCH_coordinator_throughput.json`).
//!
//! Scenarios:
//! * **mixed queue** — one cold build + k cached short requests on one
//!   worker: the seed's per-request loop convoys every short request
//!   behind the cold build; step-level scheduling admits them all and
//!   prioritizes shortest-remaining-work.
//! * **shared prefix** — k tenants building the same project on k
//!   workers: single-flight dedup executes each step once for the whole
//!   fleet instead of once per tenant.
//! * **disjoint** — k unrelated cold builds on k workers: no dedup
//!   available; step-level must not regress.
//!
//! `cargo bench --bench coordinator_throughput` (set `LAYERJET_TRIALS`
//! to override the trial count).

mod common;

use layerjet::bench::report::{fmt_secs, Table};
use layerjet::builder::CostModel;
use layerjet::coordinator::{BuildCoordinator, BuildRequest, BuildStrategy, SchedMode};
use layerjet::util::json::Json;
use std::path::Path;
use std::time::Instant;

const SHORTS: usize = 6;
const COLD_RUNS: usize = 14; // + FROM + CMD = 16 steps
const TENANTS: usize = 4;

fn write_ctx(dir: &Path, dockerfile: &str, files: &[(&str, &str)]) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join("Dockerfile"), dockerfile).unwrap();
    for (p, c) in files {
        std::fs::write(dir.join(p), c).unwrap();
    }
}

fn cold_project(dir: &Path, runs: usize) {
    let mut df = String::from("FROM ubuntu:latest\n");
    for i in 0..runs {
        df.push_str(&format!("RUN pip install coldpkg{i:02}\n"));
    }
    df.push_str("CMD [\"python\"]\n");
    write_ctx(dir, &df, &[("main.py", "print('cold')\n")]);
}

fn short_project(dir: &Path, i: usize) {
    write_ctx(
        dir,
        "FROM python:alpine\nCOPY . /app/\nCMD [\"python\", \"app/main.py\"]\n",
        &[("main.py", &format!("print('short {i}')\n"))],
    );
}

fn request(id: u64, project: &Path, tag: &str) -> BuildRequest {
    BuildRequest {
        id,
        project: project.to_path_buf(),
        tag: tag.to_string(),
        strategy: BuildStrategy::DockerRebuild,
    }
}

struct MixedPoint {
    wall_s: f64,
    /// Mean queue-wait + service of the k short requests.
    short_turnaround_s: f64,
}

/// One mixed-queue trial: 1 worker, one cold build queued ahead of
/// `SHORTS` already-cached short requests.
fn mixed_trial(root: &Path, mode: SchedMode, jobs: usize) -> MixedPoint {
    let cold = root.join("cold");
    cold_project(&cold, COLD_RUNS);
    let mut shorts = Vec::new();
    for i in 0..SHORTS {
        let dir = root.join(format!("short-{i}"));
        short_project(&dir, i);
        shorts.push(dir);
    }
    let mut coordinator = BuildCoordinator::new(&root.join("farm"), 1);
    coordinator.cost = CostModel::default();
    coordinator.jobs = jobs;
    // Warm pass: the short projects are cached (the CI steady state);
    // only the cold build has real work in the measured batch.
    let warm: Vec<BuildRequest> = shorts
        .iter()
        .enumerate()
        .map(|(i, d)| request(100 + i as u64, d, &format!("short{i}:latest")))
        .collect();
    let (outcomes, _) = coordinator.run_mode(warm, mode).unwrap();
    assert!(outcomes.iter().all(|o| o.ok), "warm pass failed: {outcomes:?}");

    let mut batch = vec![request(0, &cold, "cold:latest")];
    for (i, d) in shorts.iter().enumerate() {
        batch.push(request(1 + i as u64, d, &format!("short{i}:latest")));
    }
    let t0 = Instant::now();
    let (outcomes, _) = coordinator.run_mode(batch, mode).unwrap();
    let wall_s = t0.elapsed().as_secs_f64();
    assert!(outcomes.iter().all(|o| o.ok), "{outcomes:?}");
    let turnarounds: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.id >= 1)
        .map(|o| (o.queue_wait + o.service).as_secs_f64())
        .collect();
    MixedPoint {
        wall_s,
        short_turnaround_s: turnarounds.iter().sum::<f64>() / turnarounds.len() as f64,
    }
}

struct SharedPoint {
    wall_s: f64,
    steps_scheduled: usize,
    steps_deduped: usize,
    /// Transient step failures absorbed by the scheduler's retry policy.
    /// Zero in a fault-free bench run; the field is in the baseline so a
    /// hot retry loop (retries burning pool slots) shows up as a diff.
    steps_retried: usize,
}

/// One shared-prefix trial: `TENANTS` workers each building the same
/// project cold (their stores are per-worker, so every tenant plans a
/// full miss set — the dedup window).
fn shared_trial(root: &Path, mode: SchedMode, jobs: usize) -> SharedPoint {
    let proj = root.join("proj");
    write_ctx(
        &proj,
        "FROM python:alpine\nCOPY . /app/\nRUN pip install alpha beta\nRUN pip install gamma\n\
         RUN apt update\nRUN pip install delta\nCMD [\"python\"]\n",
        &[("main.py", "print('tenant')\n")],
    );
    let mut coordinator = BuildCoordinator::new(&root.join("farm"), TENANTS);
    coordinator.cost = CostModel::default();
    coordinator.jobs = jobs;
    let batch: Vec<BuildRequest> = (0..TENANTS)
        .map(|i| request(i as u64, &proj, "tenant:latest"))
        .collect();
    let t0 = Instant::now();
    let (outcomes, metrics) = coordinator.run_mode(batch, mode).unwrap();
    let wall_s = t0.elapsed().as_secs_f64();
    assert!(outcomes.iter().all(|o| o.ok), "{outcomes:?}");
    SharedPoint {
        wall_s,
        steps_scheduled: metrics.steps_scheduled,
        steps_deduped: metrics.steps_deduped,
        steps_retried: metrics.steps_retried,
    }
}

/// One disjoint trial: `TENANTS` workers, each building its own project.
fn disjoint_trial(root: &Path, mode: SchedMode, jobs: usize) -> f64 {
    let mut batch = Vec::new();
    for i in 0..TENANTS {
        let dir = root.join(format!("proj-{i}"));
        write_ctx(
            &dir,
            &format!(
                "FROM python:alpine\nCOPY . /app/\nRUN pip install only{i}\nCMD [\"python\"]\n"
            ),
            &[("main.py", &format!("print('{i}')\n"))],
        );
        batch.push(request(i as u64, &dir, &format!("proj{i}:latest")));
    }
    let mut coordinator = BuildCoordinator::new(&root.join("farm"), TENANTS);
    coordinator.cost = CostModel::default();
    coordinator.jobs = jobs;
    let t0 = Instant::now();
    let (outcomes, _) = coordinator.run_mode(batch, mode).unwrap();
    assert!(outcomes.iter().all(|o| o.ok), "{outcomes:?}");
    t0.elapsed().as_secs_f64()
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

fn main() {
    let n = common::trials(3);
    let base = common::bench_root("coordinator-throughput");
    let jobs = 4;

    // --- mixed queue -------------------------------------------------------
    let legs: [(&str, SchedMode, usize); 3] = [
        ("per-request jobs=1 (seed)", SchedMode::PerRequest, 1),
        ("per-request jobs=4", SchedMode::PerRequest, jobs),
        ("step-level jobs=4", SchedMode::StepLevel, jobs),
    ];
    let mut mixed: Vec<(String, Vec<MixedPoint>)> = Vec::new();
    for (name, mode, j) in legs {
        let mut points = Vec::new();
        for trial in 0..n {
            let root = base.join(format!("mixed-{name}-{trial}").replace([' ', '='], "-"));
            points.push(mixed_trial(&root, mode, j));
            let _ = std::fs::remove_dir_all(&root);
        }
        mixed.push((name.to_string(), points));
    }
    let mut table = Table::new(
        &format!("mixed queue: 1 cold ({} steps) + {SHORTS} cached shorts, 1 worker ({n} trials)", COLD_RUNS + 2),
        &["scheduling", "wall", "short turnaround (mean)"],
    );
    for (name, points) in &mixed {
        table.row(vec![
            name.clone(),
            fmt_secs(mean(&points.iter().map(|p| p.wall_s).collect::<Vec<_>>())),
            fmt_secs(mean(&points.iter().map(|p| p.short_turnaround_s).collect::<Vec<_>>())),
        ]);
    }
    table.print();

    // --- shared prefix -----------------------------------------------------
    let mut shared: Vec<(String, Vec<SharedPoint>)> = Vec::new();
    for (name, mode) in [
        ("per-request", SchedMode::PerRequest),
        ("step-level", SchedMode::StepLevel),
    ] {
        let mut points = Vec::new();
        for trial in 0..n {
            let root = base.join(format!("shared-{name}-{trial}"));
            points.push(shared_trial(&root, mode, jobs));
            let _ = std::fs::remove_dir_all(&root);
        }
        shared.push((name.to_string(), points));
    }
    let mut table = Table::new(
        &format!("shared prefix: {TENANTS} tenants, same 7-step project, {TENANTS} workers ({n} trials)"),
        &["scheduling", "wall", "steps executed", "steps deduped"],
    );
    for (name, points) in &shared {
        // Per-request mode has no pool accounting: every tenant rebuilds
        // the full project on its own worker.
        let (executed, deduped) = if name == "per-request" {
            (format!("{} (7 x {TENANTS} tenants)", 7 * TENANTS), "0".to_string())
        } else {
            (
                points[0].steps_scheduled.to_string(),
                points[0].steps_deduped.to_string(),
            )
        };
        table.row(vec![
            name.clone(),
            fmt_secs(mean(&points.iter().map(|p| p.wall_s).collect::<Vec<_>>())),
            executed,
            deduped,
        ]);
    }
    table.print();

    // --- disjoint ----------------------------------------------------------
    let mut disjoint: Vec<(String, Vec<f64>)> = Vec::new();
    for (name, mode) in [
        ("per-request", SchedMode::PerRequest),
        ("step-level", SchedMode::StepLevel),
    ] {
        let mut points = Vec::new();
        for trial in 0..n {
            let root = base.join(format!("disjoint-{name}-{trial}"));
            points.push(disjoint_trial(&root, mode, jobs));
            let _ = std::fs::remove_dir_all(&root);
        }
        disjoint.push((name.to_string(), points));
    }
    let mut table = Table::new(
        &format!("disjoint: {TENANTS} unrelated projects, {TENANTS} workers ({n} trials)"),
        &["scheduling", "wall"],
    );
    for (name, points) in &disjoint {
        table.row(vec![name.clone(), fmt_secs(mean(points))]);
    }
    table.print();

    // --- shape assertions (the acceptance bar) -----------------------------
    let seed_wall = mean(&mixed[0].1.iter().map(|p| p.wall_s).collect::<Vec<_>>());
    let pr4_short = mean(&mixed[1].1.iter().map(|p| p.short_turnaround_s).collect::<Vec<_>>());
    let pr4_wall = mean(&mixed[1].1.iter().map(|p| p.wall_s).collect::<Vec<_>>());
    let sl_wall = mean(&mixed[2].1.iter().map(|p| p.wall_s).collect::<Vec<_>>());
    let sl_short = mean(&mixed[2].1.iter().map(|p| p.short_turnaround_s).collect::<Vec<_>>());
    assert!(
        sl_wall < seed_wall,
        "step-level wall {sl_wall:.3}s must beat the seed per-request loop {seed_wall:.3}s"
    );
    assert!(
        sl_short < pr4_short,
        "step-level short turnaround {sl_short:.4}s must beat per-request {pr4_short:.4}s \
         (the convoy effect)"
    );
    let single_build_steps = 7; // FROM + COPY + 4 RUN + CMD
    let sl_shared = &shared[1].1;
    for p in sl_shared {
        assert_eq!(
            p.steps_scheduled, single_build_steps,
            "shared-prefix steps must execute exactly once across the fleet"
        );
        assert_eq!(p.steps_deduped, (TENANTS - 1) * single_build_steps);
        assert_eq!(p.steps_retried, 0, "a fault-free bench run must not spend retries");
    }
    eprintln!(
        "coordinator_throughput shape checks OK (mixed wall {:.0}ms vs seed {:.0}ms; \
         short turnaround {:.1}ms vs {:.1}ms; shared prefix 1x execution)",
        sl_wall * 1e3,
        seed_wall * 1e3,
        sl_short * 1e3,
        pr4_short * 1e3,
    );

    emit_baseline(n, &mixed, &shared, &disjoint, pr4_wall);
    let _ = std::fs::remove_dir_all(&base);
}

#[allow(clippy::type_complexity)]
fn emit_baseline(
    n: usize,
    mixed: &[(String, Vec<MixedPoint>)],
    shared: &[(String, Vec<SharedPoint>)],
    disjoint: &[(String, Vec<f64>)],
    pr4_wall: f64,
) {
    let mixed_json: Vec<Json> = mixed
        .iter()
        .map(|(name, points)| {
            Json::obj(vec![
                ("leg", Json::str(name.clone())),
                ("wall_s", Json::num(mean(&points.iter().map(|p| p.wall_s).collect::<Vec<_>>()))),
                (
                    "short_turnaround_s",
                    Json::num(mean(
                        &points.iter().map(|p| p.short_turnaround_s).collect::<Vec<_>>(),
                    )),
                ),
            ])
        })
        .collect();
    let shared_json: Vec<Json> = shared
        .iter()
        .map(|(name, points)| {
            // Per-request mode has no pool accounting: every tenant
            // rebuilds the full 7-step project on its own worker, so
            // report the analytic execution count rather than a
            // misleading 0 (the step-level leg reports its measured
            // scheduled/deduped counters).
            let (executed, deduped) = if name == "per-request" {
                ((7 * TENANTS) as f64, 0.0)
            } else {
                (
                    points[0].steps_scheduled as f64,
                    points[0].steps_deduped as f64,
                )
            };
            let retried: usize = points.iter().map(|p| p.steps_retried).sum();
            Json::obj(vec![
                ("leg", Json::str(name.clone())),
                ("wall_s", Json::num(mean(&points.iter().map(|p| p.wall_s).collect::<Vec<_>>()))),
                ("steps_executed", Json::num(executed)),
                ("steps_deduped", Json::num(deduped)),
                ("steps_retried", Json::num(retried as f64)),
            ])
        })
        .collect();
    let disjoint_json: Vec<Json> = disjoint
        .iter()
        .map(|(name, points)| {
            Json::obj(vec![
                ("leg", Json::str(name.clone())),
                ("wall_s", Json::num(mean(points))),
            ])
        })
        .collect();
    let sl_wall = mean(&mixed[2].1.iter().map(|p| p.wall_s).collect::<Vec<_>>());
    let doc = Json::obj(vec![
        ("bench", Json::str("coordinator_throughput")),
        ("measured", Json::Bool(true)),
        ("trials", Json::num(n as f64)),
        ("k_shorts", Json::num(SHORTS as f64)),
        ("cold_steps", Json::num((COLD_RUNS + 2) as f64)),
        ("tenants", Json::num(TENANTS as f64)),
        ("mixed", Json::Arr(mixed_json)),
        ("shared_prefix", Json::Arr(shared_json)),
        ("disjoint", Json::Arr(disjoint_json)),
        ("mixed_step_level_speedup_vs_per_request", Json::num(pr4_wall / sl_wall.max(1e-12))),
    ]);
    let text = doc.to_string_pretty();
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_coordinator_throughput.json", &text).expect("write baseline");
    if std::fs::write("../BENCH_coordinator_throughput.json", &text).is_ok() {
        eprintln!("wrote ../BENCH_coordinator_throughput.json");
    }
    eprintln!("wrote bench_results/BENCH_coordinator_throughput.json");
}
