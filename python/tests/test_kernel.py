"""Correctness of the L1 Pallas kernel and L2 graph.

Three-way agreement is required:
  hashlib (independent oracle)
    == ref.py (pure jnp)
    == sha256_kernel.py (Pallas, interpret mode)
    == model.hash_chunks (scan + Pallas)
plus cross-language vectors shared with the rust implementation.
"""

import hashlib

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sha256_kernel import LANE_TILE, iv_for, pallas_compress
from compile.model import build_fn, hash_chunks, hash_chunks_ref


# ---------------------------------------------------------------------------
# ref.py vs hashlib
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "data",
    [b"", b"abc", b"a" * 55, b"a" * 56, b"a" * 64, b"a" * 65, bytes(range(256)) * 7],
    ids=["empty", "abc", "len55", "len56", "len64", "len65", "1792B"],
)
def test_ref_matches_hashlib(data):
    assert ref.sha256_ref(data) == hashlib.sha256(data).hexdigest()


@settings(max_examples=25, deadline=None)
@given(st.binary(min_size=0, max_size=512))
def test_ref_matches_hashlib_random(data):
    assert ref.sha256_ref(data) == hashlib.sha256(data).hexdigest()


def test_nist_vector():
    assert (
        ref.sha256_ref(b"abc")
        == "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
    )


# ---------------------------------------------------------------------------
# chunk geometry — must mirror rust hash/engine.rs exactly
# ---------------------------------------------------------------------------


def chunk_oracle(chunk: bytes) -> str:
    msg = chunk + bytes(4096 - len(chunk)) + len(chunk).to_bytes(8, "little")
    return hashlib.sha256(msg).hexdigest()


def test_chunk_message_is_65_blocks():
    blocks = ref.chunk_message_blocks(b"xyz")
    assert blocks.shape == (65, 16)
    assert blocks.dtype == np.uint32


def test_cross_language_chunk_vectors():
    # The same constants are asserted in rust/src/hash/engine.rs tests.
    assert (
        ref.chunk_digest_ref(b"abc")
        == "9a40a5edc5fd6afe85c86c7e9d4a517b670b2d0147b680a5f0b4654154195f12"
    )
    assert (
        ref.chunk_digest_ref(b"")
        == "4f2cfec1c5dc3827cdeb42906713b37cae91e009aa0e2d211c376ccb9969b3ea"
    )


@settings(max_examples=20, deadline=None)
@given(st.binary(min_size=0, max_size=4096))
def test_chunk_digest_matches_oracle(chunk):
    assert ref.chunk_digest_ref(chunk) == chunk_oracle(chunk)


def test_oversized_chunk_rejected():
    with pytest.raises(AssertionError):
        ref.chunk_message_blocks(bytes(4097))


# ---------------------------------------------------------------------------
# Pallas kernel vs ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lanes", [LANE_TILE, 2 * LANE_TILE, 8 * LANE_TILE])
def test_pallas_compress_matches_ref(lanes):
    rng = np.random.RandomState(42 + lanes)
    h = rng.randint(0, 2**32, size=(lanes, 8), dtype=np.uint64).astype(np.uint32)
    w = rng.randint(0, 2**32, size=(lanes, 16), dtype=np.uint64).astype(np.uint32)
    got = np.asarray(pallas_compress(jnp.asarray(h), jnp.asarray(w)))
    want = np.asarray(ref.compress_ref(jnp.asarray(h), jnp.asarray(w)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**32 - 1),
    st.integers(min_value=1, max_value=4),
)
def test_pallas_compress_hypothesis(seed, tiles):
    lanes = tiles * LANE_TILE
    rng = np.random.RandomState(seed % (2**31))
    h = rng.randint(0, 2**32, size=(lanes, 8), dtype=np.uint64).astype(np.uint32)
    w = rng.randint(0, 2**32, size=(lanes, 16), dtype=np.uint64).astype(np.uint32)
    got = np.asarray(pallas_compress(jnp.asarray(h), jnp.asarray(w)))
    want = np.asarray(ref.compress_ref(jnp.asarray(h), jnp.asarray(w)))
    np.testing.assert_array_equal(got, want)


def test_pallas_rejects_ragged_lanes():
    h = jnp.zeros((LANE_TILE + 1, 8), dtype=jnp.uint32)
    w = jnp.zeros((LANE_TILE + 1, 16), dtype=jnp.uint32)
    with pytest.raises(AssertionError):
        pallas_compress(h, w)


def test_iv_broadcast():
    h = np.asarray(iv_for(4))
    assert h.shape == (4, 8)
    assert h[0, 0] == 0x6A09E667
    assert (h[0] == h[3]).all()


# ---------------------------------------------------------------------------
# L2 graph vs hashlib (whole pipeline)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lanes", [8, 64])
def test_hash_chunks_matches_hashlib(lanes):
    rng = np.random.RandomState(lanes)
    chunks = []
    for i in range(lanes):
        n = int(rng.randint(0, 4097))
        chunks.append(rng.bytes(n))
    blocks = np.stack([ref.chunk_message_blocks(c) for c in chunks])
    out = np.asarray(hash_chunks(jnp.asarray(blocks)))
    for i, chunk in enumerate(chunks):
        assert out[i].astype(">u4").tobytes().hex() == chunk_oracle(chunk), f"lane {i}"


def test_hash_chunks_pallas_equals_ref_path():
    rng = np.random.RandomState(7)
    blocks = rng.randint(
        0, 2**32, size=(8, ref.BLOCKS_PER_CHUNK, 16), dtype=np.uint64
    ).astype(np.uint32)
    a = np.asarray(hash_chunks(jnp.asarray(blocks)))
    b = np.asarray(hash_chunks_ref(jnp.asarray(blocks)))
    np.testing.assert_array_equal(a, b)


def test_build_fn_shapes():
    fn, (blocks_spec, kc_spec) = build_fn(8)
    assert blocks_spec.shape == (8, ref.BLOCKS_PER_CHUNK, 16)
    assert kc_spec.shape == (64,)
    blocks = np.zeros(blocks_spec.shape, dtype=np.uint32)
    (out,) = fn(jnp.asarray(blocks), jnp.asarray(ref.K))
    assert out.shape == (8, 8)
    assert out.dtype == jnp.uint32


def test_lanes_are_independent():
    # Changing one lane's chunk must not affect any other lane's digest.
    base = np.stack([ref.chunk_message_blocks(b"lane%d" % i) for i in range(8)])
    out1 = np.asarray(hash_chunks(jnp.asarray(base)))
    changed = base.copy()
    changed[3] = ref.chunk_message_blocks(b"mutated!")
    out2 = np.asarray(hash_chunks(jnp.asarray(changed)))
    for i in range(8):
        if i == 3:
            assert not (out1[i] == out2[i]).all()
        else:
            np.testing.assert_array_equal(out1[i], out2[i])
