"""AOT pipeline: lower the L2 hash graph to HLO **text** artifacts.

HLO text — not serialized ``HloModuleProto`` — is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Run once at build time (`make artifacts`); the rust binary then loads
``artifacts/hash_chunks_l{N}.hlo.txt`` through PJRT and Python never runs
again.  A ``manifest.json`` lists the variants so the rust runtime can
pick lane counts without directory scraping.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.ref import BLOCKS_PER_CHUNK, K, chunk_message_blocks
from .model import build_fn

# Lane-count variants to export. The runtime batches full 64-lane calls
# and drains the tail with the 8-lane variant.
LANE_VARIANTS = (8, 64)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def self_check(lanes: int) -> None:
    """The lowered graph must reproduce hashlib on a sample batch."""
    fn, _ = build_fn(lanes)
    chunks = [bytes([i] * (97 * (i + 1) % 4097)) for i in range(lanes)]
    blocks = np.stack([chunk_message_blocks(c) for c in chunks])
    (out,) = fn(blocks, np.asarray(K))
    out = np.asarray(out)
    for i, chunk in enumerate(chunks):
        msg = chunk + bytes(4096 - len(chunk)) + len(chunk).to_bytes(8, "little")
        expect = hashlib.sha256(msg).hexdigest()
        got = out[i].astype(">u4").tobytes().hex()
        assert got == expect, f"lane {i}: {got} != {expect}"


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--out", default=None, help="(compat) single-file mode marker")
    args = parser.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "format": "hlo-text",
        "blocks_per_chunk": BLOCKS_PER_CHUNK,
        "variants": [],
    }
    for lanes in LANE_VARIANTS:
        self_check(lanes)
        fn, (blocks_spec, kc_spec) = build_fn(lanes)
        lowered = jax.jit(fn).lower(blocks_spec, kc_spec)
        text = to_hlo_text(lowered)
        name = f"hash_chunks_l{lanes}.hlo.txt"
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["variants"].append({"lanes": lanes, "file": name, "bytes": len(text)})
        print(f"wrote {path} ({len(text)} chars), self-check OK", file=sys.stderr)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    print(f"artifacts complete: {out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
