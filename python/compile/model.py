"""L2 — the JAX compute graph: batched multi-block SHA-256.

The rust coordinator's unit of hashing work is a **chunk batch**: a dense
``uint32[lanes, 65, 16]`` tensor (one 4 KiB chunk per lane, pre-padded on
the rust side to the fixed 65-block message — see hash/engine.rs).  This
module folds the 65 blocks with ``lax.scan``, each step calling the L1
Pallas compression kernel, producing one ``uint32[lanes, 8]`` digest row
per lane.

Design choices (perf pass, DESIGN.md §8):
 * ``scan`` over the block axis rather than a Python loop: one compiled
   body instead of 65 inlined compressions keeps the HLO small and lets
   XLA pipeline the per-step loads;
 * blocks are transposed to ``[65, lanes, 16]`` once so each scan step
   reads a contiguous slice;
 * the state is donated through the scan carry — no per-step allocation.

``aot.py`` lowers ``hash_chunks`` at several fixed lane counts to HLO
text; the rust runtime picks the variant that fits the batch and pads the
tail.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.sha256_kernel import iv_for, pallas_compress
from .kernels.ref import BLOCKS_PER_CHUNK, compress_ref


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def hash_chunks(
    blocks: jnp.ndarray,
    kc: jnp.ndarray | None = None,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Hash a chunk batch.

    blocks: uint32[lanes, 65, 16] — pre-padded chunk messages.
    kc: uint32[64] round-constant table; `None` uses the trace-time
    constant (test path). The AOT artifact takes it as a parameter so the
    HLO-text interchange never elides it (see kernels.sha256_kernel.k_table).
    Returns uint32[lanes, 8] — one digest (as big-endian words) per lane.
    """
    lanes, nblocks, words = blocks.shape
    assert nblocks == BLOCKS_PER_CHUNK and words == 16, blocks.shape
    # [65, lanes, 16]: contiguous per-step slices for the scan.
    seq = jnp.transpose(blocks.astype(jnp.uint32), (1, 0, 2))

    def step(h, w):
        if use_pallas:
            return pallas_compress(h, w, kc=kc), None
        return compress_ref(h, w), None

    h0 = iv_for(lanes)
    h_final, _ = jax.lax.scan(step, h0, seq)
    return h_final


def hash_chunks_ref(blocks: jnp.ndarray) -> jnp.ndarray:
    """Reference path (pure jnp, no Pallas) for A/B tests."""
    return hash_chunks(blocks, use_pallas=False)


def build_fn(lanes: int):
    """A concrete-shape entry point for AOT lowering.

    Signature: ``fn(blocks, kc) -> (digests,)`` — the round-constant
    table is a runtime parameter (HLO text elides large constants; see
    kernels.sha256_kernel.k_table).
    """

    def fn(blocks, kc):
        # return_tuple lowering expects a tuple result.
        return (hash_chunks(blocks, kc=kc),)

    blocks_spec = jax.ShapeDtypeStruct((lanes, BLOCKS_PER_CHUNK, 16), jnp.uint32)
    kc_spec = jax.ShapeDtypeStruct((64,), jnp.uint32)
    return fn, (blocks_spec, kc_spec)
