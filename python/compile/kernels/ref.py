"""Pure-jnp SHA-256 reference: the correctness oracle for the Pallas kernel.

Implements FIPS 180-4 exactly as the paper describes it (§III.B, Eq. 1):
pad to a multiple of 512 bits, split into 16-word blocks, and fold
``H(i) = H(i-1) + C_{M(i)}(H(i-1))``.  Everything here is vectorized over
a leading *lane* axis so a batch of independent streams (one per 4 KiB
chunk of layer content) hashes in one call — the workload the rust
coordinator ships to the AOT executable.

Cross-checked against ``hashlib`` in python/tests/test_kernel.py and
against the from-scratch rust implementation via shared test vectors.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# FIPS 180-4 §5.3.3 initial hash value.
IV = np.array(
    [
        0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A,
        0x510E527F, 0x9B05688C, 0x1F83D9AB, 0x5BE0CD19,
    ],
    dtype=np.uint32,
)

# FIPS 180-4 §4.2.2 round constants.
K = np.array(
    [
        0x428A2F98, 0x71374491, 0xB5C0FBCF, 0xE9B5DBA5, 0x3956C25B, 0x59F111F1,
        0x923F82A4, 0xAB1C5ED5, 0xD807AA98, 0x12835B01, 0x243185BE, 0x550C7DC3,
        0x72BE5D74, 0x80DEB1FE, 0x9BDC06A7, 0xC19BF174, 0xE49B69C1, 0xEFBE4786,
        0x0FC19DC6, 0x240CA1CC, 0x2DE92C6F, 0x4A7484AA, 0x5CB0A9DC, 0x76F988DA,
        0x983E5152, 0xA831C66D, 0xB00327C8, 0xBF597FC7, 0xC6E00BF3, 0xD5A79147,
        0x06CA6351, 0x14292967, 0x27B70A85, 0x2E1B2138, 0x4D2C6DFC, 0x53380D13,
        0x650A7354, 0x766A0ABB, 0x81C2C92E, 0x92722C85, 0xA2BFE8A1, 0xA81A664B,
        0xC24B8B70, 0xC76C51A3, 0xD192E819, 0xD6990624, 0xF40E3585, 0x106AA070,
        0x19A4C116, 0x1E376C08, 0x2748774C, 0x34B0BCB5, 0x391C0CB3, 0x4ED8AA4A,
        0x5B9CCA4F, 0x682E6FF3, 0x748F82EE, 0x78A5636F, 0x84C87814, 0x8CC70208,
        0x90BEFFFA, 0xA4506CEB, 0xBEF9A3F7, 0xC67178F2,
    ],
    dtype=np.uint32,
)

# Chunk geometry shared with the rust side (hash/engine.rs): a 4 KiB chunk
# plus an 8-byte little-endian length suffix, SHA-padded to exactly 65
# 64-byte blocks.
CHUNK_SIZE = 4096
BLOCKS_PER_CHUNK = 65
WORDS_PER_BLOCK = 16


def _rotr(x: jnp.ndarray, n: int) -> jnp.ndarray:
    return (x >> np.uint32(n)) | (x << np.uint32(32 - n))


def compress_ref(h: jnp.ndarray, w16: jnp.ndarray) -> jnp.ndarray:
    """One SHA-256 compression: fold one 16-word block into the state.

    h:   uint32[..., 8]   current state
    w16: uint32[..., 16]  message block (big-endian words)
    Returns the new uint32[..., 8] state.

    The round loop is a ``fori_loop`` with a sliding 16-word message
    window. (An unrolled 64-round body triggers a pathological XLA-CPU
    compile once jitted, so both this reference and the Pallas kernel use
    the loop form; the *independent* correctness oracle is ``hashlib``,
    which the tests compare against at every level.)
    """
    import jax

    h = h.astype(jnp.uint32)
    w16 = w16.astype(jnp.uint32)
    # Same 8-element-piece trick as the kernel: HLO text elides large
    # constants, and this reference also gets lowered (hash_chunks_ref).
    kc = jnp.concatenate(
        [jnp.asarray(K[i * 8 : (i + 1) * 8], dtype=jnp.uint32) for i in range(8)]
    )

    def round_body(t, carry):
        a, b, c, d, e, f, g, hh, window = carry
        wt = window[..., 0]
        big_s1 = _rotr(e, 6) ^ _rotr(e, 11) ^ _rotr(e, 25)
        ch = (e & f) ^ (~e & g)
        t1 = hh + big_s1 + ch + kc[t] + wt
        big_s0 = _rotr(a, 2) ^ _rotr(a, 13) ^ _rotr(a, 22)
        maj = (a & b) ^ (a & c) ^ (b & c)
        t2 = big_s0 + maj
        # Schedule: w[t+16] = w[t] + σ0(w[t+1]) + w[t+9] + σ1(w[t+14]).
        w1 = window[..., 1]
        w14 = window[..., 14]
        s0 = _rotr(w1, 7) ^ _rotr(w1, 18) ^ (w1 >> np.uint32(3))
        s1 = _rotr(w14, 17) ^ _rotr(w14, 19) ^ (w14 >> np.uint32(10))
        nxt = window[..., 0] + s0 + window[..., 9] + s1
        window = jnp.concatenate([window[..., 1:], nxt[..., None]], axis=-1)
        return (t1 + t2, a, b, c, d + t1, e, f, g, window)

    init = tuple(h[..., i] for i in range(8)) + (w16,)
    a, b, c, d, e, f, g, hh = jax.lax.fori_loop(0, 64, round_body, init)[:8]
    out = jnp.stack([a, b, c, d, e, f, g, hh], axis=-1)
    return h + out


import functools as _functools
import jax as _jax


@_functools.partial(_jax.jit)
def _fold_blocks(h0: jnp.ndarray, blocks: jnp.ndarray) -> jnp.ndarray:
    seq = jnp.transpose(blocks.astype(jnp.uint32), (1, 0, 2))

    def step(h, w):
        return compress_ref(h, w), None

    h, _ = _jax.lax.scan(step, h0.astype(jnp.uint32), seq)
    return h


def hash_blocks_ref(h0: jnp.ndarray, blocks: jnp.ndarray) -> jnp.ndarray:
    """Fold a sequence of blocks: blocks uint32[lanes, n, 16] -> [lanes, 8].

    Jitted (scan over the block axis): the oracle is called thousands of
    times by the hypothesis sweeps, and eager per-round dispatch would
    dominate the test suite's runtime.
    """
    return _fold_blocks(h0, blocks)


# ---------------------------------------------------------------------------
# numpy-side helpers used by tests and by aot.py's self-check.
# ---------------------------------------------------------------------------


def pad_message(data: bytes) -> np.ndarray:
    """SHA-256 padding: returns uint32[n_blocks, 16] big-endian words."""
    bitlen = len(data) * 8
    msg = bytearray(data)
    msg.append(0x80)
    while len(msg) % 64 != 56:
        msg.append(0)
    msg += bitlen.to_bytes(8, "big")
    arr = np.frombuffer(bytes(msg), dtype=">u4").astype(np.uint32)
    return arr.reshape(-1, WORDS_PER_BLOCK)


def digest_hex(state: np.ndarray) -> str:
    """Final 8-word state -> hex digest string."""
    return np.asarray(state, dtype=np.uint32).astype(">u4").tobytes().hex()


def sha256_ref(data: bytes) -> str:
    """Full SHA-256 of a byte string, via compress_ref. For oracle tests."""
    blocks = pad_message(data)
    h = jnp.asarray(IV)[None, :]
    out = hash_blocks_ref(h, jnp.asarray(blocks)[None, :, :])
    return digest_hex(np.asarray(out)[0])


def chunk_message_blocks(chunk: bytes) -> np.ndarray:
    """The fixed 65-block padded message of one chunk, mirroring the rust
    ``hash::engine::chunk_message_blocks`` byte-for-byte:
    ``chunk ∥ 0^(4096-len) ∥ u64_le(len)`` then SHA padding to 4160 bytes.
    Returns uint32[65, 16].
    """
    assert len(chunk) <= CHUNK_SIZE, f"chunk too large: {len(chunk)}"
    msg = bytearray(BLOCKS_PER_CHUNK * 64)
    msg[: len(chunk)] = chunk
    msg[CHUNK_SIZE : CHUNK_SIZE + 8] = len(chunk).to_bytes(8, "little")
    msg[CHUNK_SIZE + 8] = 0x80
    bitlen = (CHUNK_SIZE + 8) * 8
    msg[-8:] = bitlen.to_bytes(8, "big")
    arr = np.frombuffer(bytes(msg), dtype=">u4").astype(np.uint32)
    return arr.reshape(BLOCKS_PER_CHUNK, WORDS_PER_BLOCK)


def chunk_digest_ref(chunk: bytes) -> str:
    """Digest of one chunk via the reference path (hex)."""
    blocks = chunk_message_blocks(chunk)
    h = jnp.asarray(IV)[None, :]
    out = hash_blocks_ref(h, jnp.asarray(blocks)[None, :, :])
    return digest_hex(np.asarray(out)[0])
