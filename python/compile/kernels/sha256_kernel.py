"""L1 — the SHA-256 compression function as a Pallas kernel.

The compute hot-spot of Docker's integrity mechanism (and of the paper's
checksum-bypass step) is hashing layer bytes.  LayerJet's chunk digest
turns that into a data-parallel problem: every 4 KiB chunk is an
independent 65-block SHA-256 stream, so the *lane* axis (one lane per
chunk) maps onto the TPU vector unit while the strictly sequential
64-round dependency stays inside the kernel.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
 * the 8-word state and 16-word message block for a lane tile live in
   VMEM (`BlockSpec` below tiles the lane axis);
 * the kernel is pure uint32 bitwise/add work — VPU-bound, no MXU;
 * the message schedule uses a rolling 16-word window (8 KiB/128 lanes)
   rather than the expanded 64-word form (32 KiB) to keep the VMEM
   footprint per grid step minimal;
 * ``interpret=True`` everywhere: the CPU PJRT client cannot execute
   Mosaic custom-calls, so the kernel lowers to plain HLO. Real-TPU
   performance is *estimated* from the tiling structure, never measured
   here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import IV, K


def k_table() -> jnp.ndarray:
    """The 64 round constants as a trace-time array (test path only).

    IMPORTANT: the AOT path must NOT bake K in as a constant. The HLO
    **text** printer elides constants larger than a few elements
    (`constant({...})`), HLO text is our AOT interchange format, and an
    elided constant silently round-trips as garbage — the lowered graph
    therefore takes K as a *runtime argument* supplied by the rust
    caller (see model.build_fn and runtime/mod.rs)."""
    return jnp.asarray(K, dtype=jnp.uint32)


# Lane tile per grid step. 8 keeps the interpret-mode overhead low while
# the structure (grid over lane tiles) is what a real TPU build would use
# with 128-lane tiles.
LANE_TILE = 8


def _rotr(x, n: int):
    return (x >> np.uint32(n)) | (x << np.uint32(32 - n))


def _compress_rounds(h, w, kc):
    """64 SHA-256 rounds over a lane tile.

    h: uint32[tile, 8], w: uint32[tile, 16], kc: uint32[64] (round
    constants, passed as a kernel input — Pallas forbids captured
    constants) -> uint32[tile, 8]

    The round loop is a ``fori_loop`` with a **rolling message-schedule
    window**: the carry holds the state vectors plus ``w[t..t+15]`` as a
    ``[tile, 16]`` array. Each step consumes ``window[:, 0]`` and appends
    ``w[t+16] = w[t] + σ0(w[t+1]) + w[t+9] + σ1(w[t+14])`` (computed —
    harmlessly — even for the final rounds). A small loop body keeps the
    traced graph tiny, which matters twice: interpret-mode compilation
    stays fast, and the AOT HLO the rust side compiles stays compact.
    """
    def round_body(t, carry):
        a, b, c, d, e, f, g, hh, window = carry
        wt = window[:, 0]
        big_s1 = _rotr(e, 6) ^ _rotr(e, 11) ^ _rotr(e, 25)
        ch = (e & f) ^ (~e & g)
        t1 = hh + big_s1 + ch + kc[t] + wt
        big_s0 = _rotr(a, 2) ^ _rotr(a, 13) ^ _rotr(a, 22)
        maj = (a & b) ^ (a & c) ^ (b & c)
        t2 = big_s0 + maj
        # Schedule: w[t+16] from the current window.
        w1 = window[:, 1]
        w14 = window[:, 14]
        s0 = _rotr(w1, 7) ^ _rotr(w1, 18) ^ (w1 >> np.uint32(3))
        s1 = _rotr(w14, 17) ^ _rotr(w14, 19) ^ (w14 >> np.uint32(10))
        nxt = window[:, 0] + s0 + window[:, 9] + s1
        window = jnp.concatenate([window[:, 1:], nxt[:, None]], axis=1)
        # (a, b, c, d, e, f, g, h) after the round:
        return (t1 + t2, a, b, c, d + t1, e, f, g, window)

    init = tuple(h[:, i] for i in range(8)) + (w,)
    a, b, c, d, e, f, g, hh, _ = jax.lax.fori_loop(0, 64, round_body, init)
    out = jnp.stack([a, b, c, d, e, f, g, hh], axis=-1)
    return h + out


def _compress_kernel(k_ref, h_ref, w_ref, o_ref):
    """Pallas kernel body: one compression per lane of the tile."""
    o_ref[...] = _compress_rounds(h_ref[...], w_ref[...], k_ref[...])


@functools.partial(jax.jit, static_argnames=("lane_tile",))
def pallas_compress(
    h: jnp.ndarray,
    w: jnp.ndarray,
    lane_tile: int = LANE_TILE,
    kc: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Batched SHA-256 compression via the Pallas kernel.

    h: uint32[lanes, 8], w: uint32[lanes, 16] -> uint32[lanes, 8].
    ``lanes`` must be a multiple of ``lane_tile``. ``kc`` is the round
    constant table (uint32[64]); it defaults to the trace-time table but
    the AOT path passes it through as a runtime argument (see k_table).
    """
    if kc is None:
        kc = k_table()
    lanes = h.shape[0]
    assert lanes % lane_tile == 0, f"lanes {lanes} % tile {lane_tile} != 0"
    grid = (lanes // lane_tile,)
    return pl.pallas_call(
        _compress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((64,), lambda i: (0,)),
            pl.BlockSpec((lane_tile, 8), lambda i: (i, 0)),
            pl.BlockSpec((lane_tile, 16), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((lane_tile, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lanes, 8), jnp.uint32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(kc.astype(jnp.uint32), h.astype(jnp.uint32), w.astype(jnp.uint32))


def iv_for(lanes: int) -> jnp.ndarray:
    """Broadcast initial state for a lane batch."""
    return jnp.broadcast_to(jnp.asarray(IV, dtype=jnp.uint32), (lanes, 8))
